"""Multi-device semantic checks, run in a subprocess with 8 fake CPU devices.

Invoked as ``python -m tests.dist_harness <case> [<case> ...]`` by
tests/test_distributed.py (jax pins the device count at first init, so the
main pytest process — which must see ONE device for the smoke tests — cannot
host these).

Each case builds a tiny TP+FSDP model three ways and asserts gradients and
outputs match a single-device dense reference EXACTLY (fp32 end to end):

  * gather_group (the parametrization custom_vjp) on a raw param tree
  * apply_stack vanilla (scan + remat policy, autodiff backward)
  * apply_stack prefetch (the hand-scheduled custom_vjp) under every
    combination of the Table-6 schedule flags and every bucket mode

across mesh layouts: 2D (data,model), 3D HSDP (pod,data,model; shard in-pod)
and 3D global ZeRO-3 (shard over pod+data).

The `pipeline` case covers paper SS4's pipeline-parallel composition: GPipe
and 1F1B schedules under (pipe, data, model) meshes with FSDP bucket gathers
active INSIDE each pipelined stage, asserted exactly against the sequential
dense reference (losses, parameter grads, and d/d(xs)) across bucket modes.

The `trainer_pipeline` case covers the unified `parallelize()` path — the
full-LM stage partition (embedding on stage 0, layer slices, head+loss on
the last stage, replicated tied embeddings): pp=2 vs the pp=1 baseline must
agree exactly on losses, assembled gradients, and one AdamW step.  The
`trainer_smoke_a/b` cases run every registered arch 2 Trainer steps (plus a
staged checkpoint) on a pp2 x dp2 x tp2 mesh.

The `pipeline_v2` case covers the PR-6 schedules: interleaved 1F1B (V=2
virtual stage chunks per rank) and zb (W-split zero-bubble) at pp2 x dp4
must reproduce pp=1 exactly (losses, grads, AdamW steps), plus zamba2's
uneven zero-padded stage partition over two chained train steps and the
stage_pre-hoist trace-count regression.

The `quant` case covers quantized collectives (kernels/quant +
DistConfig.comm_precision): comm_precision="bf16" must be BIT-exact vs the
default path over two chained AdamW steps, while fp8_ag / fp8 / fp8_ef /
auto must track the bf16 reference within documented EF-theory tolerance
(loss rtol 5e-2, per-coordinate weight drift <= 4*lr*steps) with the
error-feedback accumulator present exactly when DistConfig.needs_ef.

The `context` case covers context parallelism (core/context.py): zigzag
sequence sharding + ring attention over the ctx axis — cp2 x dp2 must
reproduce the cp1 x dp4 baseline exactly (losses, assembled grads, one
AdamW step) for dense + gemma2, and the 4-axis pp2 x dp2 x cp2 composition
must reproduce pp1 x dp4.
"""

from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # device count must be set before jax init
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")
    )

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import (BucketPlan, DistConfig, ParamMeta, apply_stack,
                        from_storage, make_mesh, replicate_tree, to_storage)
from repro.core.bucketing import per_param_plan, whole_block_plan

D, H, B, L = 8, 16, 16, 4  # model dim, hidden, global batch, layers


# --------------------------------------------------------------------------
# Tiny TP-aware block (see module docstring for why each param is shaped so).
# --------------------------------------------------------------------------
def block_metas(cfg: DistConfig):
    return {
        "w1": ParamMeta("w1", (D, H), tp_dim=1),
        "b": ParamMeta("b", (H,), tp_dim=0),
        "g": ParamMeta("g", (1,), tp_dim=None),      # consumed TP-varying
        "w2": ParamMeta("w2", (H, D), tp_dim=0),
        "scale": ParamMeta("scale", (D,), tp_dim=None),  # consumed replicated
    }


def init_block(key):
    ks = jax.random.split(key, 5)
    return {
        "w1": jax.random.normal(ks[0], (D, H)) * 0.3,
        "b": jax.random.normal(ks[1], (H,)) * 0.1,
        "g": jnp.ones((1,)) * 0.7,
        "w2": jax.random.normal(ks[2], (H, D)) * 0.3,
        "scale": 1.0 + jax.random.normal(ks[3], (D,)) * 0.1,
    }


def block_local(p, consts, x, cfg: DistConfig):
    """TP-local compute: w1 col-parallel, w2 row-parallel + psum."""
    h = jnp.tanh(x @ p["w1"])          # (b, H/tp)
    h = h * p["g"][0] + p["b"]
    o = h @ p["w2"]                    # partial sums over H
    if cfg.tp_size > 1:
        o = lax.psum(o, cfg.tp_axis)
    y = x + o * p["scale"] + consts["shift"]
    return y, {"l2": jnp.sum(h.astype(jnp.float32) ** 2)}


def block_dense(p, consts, x):
    h = jnp.tanh(x @ p["w1"])
    h = h * p["g"][0] + p["b"]
    o = h @ p["w2"]
    y = x + o * p["scale"] + consts["shift"]
    return y, jnp.sum(h.astype(jnp.float32) ** 2)


def dense_loss(stacked_full, consts, x, dp_total=1):
    """Reference objective. The aux (l2) term is a *sum* over all elements;
    under the per-device-mean gradient convention (global objective = mean
    over DP ranks of local losses) the dense equivalent scales it by
    1/dp_total — see run_stack_case."""
    def body(c, p):
        y, l2 = block_dense(p, consts, c)
        return y, l2
    y, l2s = lax.scan(body, x, stacked_full)
    return jnp.mean(y**2) + 1e-3 * jnp.sum(l2s) / dp_total, y


# --------------------------------------------------------------------------
def fp32_cfg(mesh_axes, mesh_shape, fsdp_axes, **kw) -> DistConfig:
    return DistConfig(
        mesh_axes=mesh_axes, mesh_shape=mesh_shape, fsdp_axes=fsdp_axes,
        param_dtype=jnp.float32, reduce_dtype=jnp.float32,
        storage_dtype=jnp.float32, **kw,
    )


def stacked_storage(stacked_full, metas, cfg):
    """(L, ...)-stacked full params -> (L, storage...) layout."""
    return {
        k: jnp.stack([to_storage(stacked_full[k][i], metas[k], cfg)
                      for i in range(L)])
        for k in metas
    }


def run_stack_case(cfg: DistConfig, plan, tag: str):
    mesh = make_mesh(cfg)
    metas = block_metas(cfg)
    key = jax.random.PRNGKey(0)
    stacked_full = {
        k: jnp.stack([init_block(jax.random.fold_in(key, i))[k]
                      for i in range(L)])
        for k in block_metas(cfg)
    }
    x = jax.random.normal(jax.random.PRNGKey(7), (B, D))
    consts = {"shift": jnp.full((D,), 0.01)}

    dp = cfg.dp_total

    # dense reference ------------------------------------------------------
    ref_loss = dense_loss(stacked_full, consts, x, dp)[0]
    ref_grads, ref_dx = jax.grad(
        lambda s, xx: dense_loss(s, consts, xx, dp)[0], argnums=(0, 1))(
            stacked_full, x)

    # sharded --------------------------------------------------------------
    storage = stacked_storage(stacked_full, metas, cfg)
    blk = functools.partial(block_local, cfg=cfg)

    def local_loss(storage, consts, x):
        y, aux = apply_stack(blk, metas, cfg, storage, consts, x, plan=plan)
        l2 = aux["l2"]
        if cfg.tp_size > 1:
            l2 = lax.psum(l2, cfg.tp_axis)
        # per-device loss: local-mean main term + the full TP-summed aux for
        # the locally owned rows. Global objective = pmean over DP ranks.
        return jnp.mean(y**2) + 1e-3 * l2

    def step(storage, consts, x):
        (loss, _), grads = jax.value_and_grad(
            lambda s: (local_loss(s, consts, x), 0.0), has_aux=True)(storage)
        dx = jax.grad(lambda xx: local_loss(storage, consts, xx))(x)
        loss = lax.pmean(loss, tuple(a for a in cfg.mesh_axes
                                     if a != cfg.tp_axis))
        return loss, grads, dx

    dp_axes = tuple(a for a in cfg.mesh_axes if a != cfg.tp_axis)
    in_specs = (
        {k: metas[k].stacked_storage_spec(cfg) for k in metas},
        {"shift": P()},
        P(dp_axes),
    )
    out_specs = (
        P(),
        {k: metas[k].stacked_storage_spec(cfg) for k in metas},
        P(dp_axes),
    )
    fn = jax.jit(shard_map(step, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs))
    loss, grads, dx = fn(storage, consts, x)

    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=2e-5,
                               err_msg=f"{tag}: loss mismatch")
    # d(local_loss)/d(local x) is dp x the dense d(global mean)/dx
    np.testing.assert_allclose(np.asarray(dx) / dp, np.asarray(ref_dx),
                               rtol=2e-4, atol=2e-5,
                               err_msg=f"{tag}: dx mismatch")
    for k in metas:
        got = jnp.stack([from_storage(grads[k][i], metas[k], cfg)
                         for i in range(L)])
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref_grads[k]), rtol=2e-4, atol=2e-5,
            err_msg=f"{tag}: grad mismatch for {k}")
    print(f"PASS {tag}")


MESHES = {
    "2d": (("data", "model"), (4, 2), ("data",)),
    "hsdp": (("pod", "data", "model"), (2, 2, 2), ("data",)),
    "zero3": (("pod", "data", "model"), (2, 2, 2), ("pod", "data")),
}


def case_roundtrip():
    cfg = fp32_cfg(*MESHES["2d"])
    metas = block_metas(cfg)
    p = init_block(jax.random.PRNGKey(3))
    for k, m in metas.items():
        rt = from_storage(to_storage(p[k], m, cfg), m, cfg)
        np.testing.assert_allclose(np.asarray(rt), np.asarray(p[k]),
                                   err_msg=f"roundtrip {k}")
    print("PASS roundtrip")


def case_gather_values():
    """gather_group reconstructs exact full params on every device."""
    for mesh_name, spec in MESHES.items():
        cfg = fp32_cfg(*spec)
        mesh = make_mesh(cfg)
        metas = block_metas(cfg)
        p = init_block(jax.random.PRNGKey(3))
        storage = {k: to_storage(p[k], metas[k], cfg) for k in metas}

        def f(storage):
            full = replicate_tree(storage, metas, cfg,
                                  whole_block_plan(metas))
            # re-assemble the TP-sharded params for comparison outside
            return full

        out_specs = {}
        for k, m in metas.items():
            if m.tp_dim is None:
                out_specs[k] = P()
            else:
                axes = [None] * len(m.global_shape)
                axes[m.tp_dim] = cfg.tp_axis
                out_specs[k] = P(*axes)
        # gathered outputs are value-replicated but vma can't prove it —
        # this diagnostic case opts out of the replication check
        fn = jax.jit(shard_map(
            f, mesh=mesh,
            in_specs=({k: metas[k].storage_spec(cfg) for k in metas},),
            out_specs=out_specs, check_vma=False))
        full = fn(storage)
        for k in metas:
            np.testing.assert_allclose(
                np.asarray(full[k]), np.asarray(p[k]),
                err_msg=f"gather {mesh_name}/{k}")
    print("PASS gather_values")


def case_vanilla():
    for mesh_name, spec in MESHES.items():
        for bucket, plan_fn in [("none", per_param_plan),
                                ("block", whole_block_plan)]:
            cfg = fp32_cfg(*spec, reorder=False, remat="fsdp_only")
            run_stack_case(cfg, plan_fn(block_metas(cfg)),
                           f"vanilla/{mesh_name}/bucket={bucket}")


def case_prefetch():
    for mesh_name, spec in MESHES.items():
        for agf in (True, False):
            for agb in (True, False):
                for rsd in (True, False):
                    cfg = fp32_cfg(*spec, reorder=True,
                                   ag_before_wait_fwd=agf,
                                   ag_before_wait_bwd=agb, rs_delay=rsd)
                    run_stack_case(
                        cfg, whole_block_plan(block_metas(cfg)),
                        f"prefetch/{mesh_name}/agf={agf}/agb={agb}/rsd={rsd}")


def case_prefetch_buckets():
    """Prefetch path under per-param and custom two-bucket plans."""
    cfg = fp32_cfg(*MESHES["2d"], reorder=True)
    metas = block_metas(cfg)
    run_stack_case(cfg, per_param_plan(metas), "prefetch/bucket=none")
    custom = BucketPlan((("w1", "b"), ("g", "w2", "scale")))
    run_stack_case(cfg, custom, "prefetch/bucket=custom2")


def case_remat_modes():
    for remat in ("none", "fsdp_only", "full"):
        cfg = fp32_cfg(*MESHES["2d"], reorder=False, remat=remat)
        run_stack_case(cfg, whole_block_plan(block_metas(cfg)),
                       f"vanilla/remat={remat}")


CASES = {
    "roundtrip": case_roundtrip,
    "gather_values": case_gather_values,
    "vanilla": case_vanilla,
    "prefetch": case_prefetch,
    "prefetch_buckets": case_prefetch_buckets,
    "remat_modes": case_remat_modes,
}





# --------------------------------------------------------------------------
# Every architecture: (2 data x 4 model) mesh == single-device reference.
# Exercises TP/SP/EP/head-padding/replicated-kv paths end to end.
# --------------------------------------------------------------------------
def case_models():
    from repro.models.common import ShapeConfig
    from repro.models.registry import ARCH_IDS, get_arch
    from repro.models import runtime as RT

    for arch in ARCH_IDS:
        if arch == "llama3_8b":
            continue   # same code path as deepseek/qwen3
        cfg, model = get_arch(arch, smoke=True)
        dcfg1 = fp32_cfg(("data", "model"), (1, 1), ("data",))
        dcfg8 = fp32_cfg(("data", "model"), (2, 4), ("data",))

        B = 4
        if arch == "seamless_m4t_large_v2":
            S_total = 64
        elif arch == "internvl2_26b":
            S_total = 40           # 8 img + 32 text
        else:
            S_total = 32
        shape = ShapeConfig("t", S_total, B, "train")

        full = model.init_full(jax.random.PRNGKey(0), dcfg8)
        key = jax.random.PRNGKey(1)
        batch = {}
        for k, sd in model.input_specs(shape, dcfg8).items():
            key = jax.random.fold_in(key, 7)
            if jnp.issubdtype(sd.dtype, jnp.integer):
                batch[k] = jax.random.randint(key, sd.shape, 0, cfg.vocab)
            elif k == "valid":
                batch[k] = jnp.ones(sd.shape, sd.dtype)
            else:
                batch[k] = jax.random.normal(key, sd.shape, sd.dtype) * 0.3

        results = {}
        for name, dcfg in [("1dev", dcfg1), ("8dev", dcfg8)]:
            metas = model.metas(dcfg)
            storage = {k: RT.tree_to_storage(full[k], metas[k], dcfg)
                       for k in full}
            step = RT.make_loss_step(model, dcfg)
            specs = RT.model_storage_specs(model, dcfg)
            fn, _ = RT.wrap_step(model, dcfg, shape, step, (P(), specs))
            loss, grads = fn(storage, batch)
            gfull = {k: RT.tree_from_storage(grads[k], metas[k], dcfg)
                     for k in grads}
            results[name] = (float(loss), gfull)

        l1, g1 = results["1dev"]
        l8, g8 = results["8dev"]
        np.testing.assert_allclose(l8, l1, rtol=5e-5,
                                   err_msg=f"{arch}: loss mesh mismatch")
        flat1 = dict(jax.tree_util.tree_flatten_with_path(g1)[0] and
                     [(jax.tree_util.keystr(p), v) for p, v in
                      jax.tree_util.tree_flatten_with_path(g1)[0]])
        flat8 = dict([(jax.tree_util.keystr(p), v) for p, v in
                      jax.tree_util.tree_flatten_with_path(g8)[0]])
        for k in flat1:
            np.testing.assert_allclose(
                np.asarray(flat8[k]), np.asarray(flat1[k]),
                rtol=3e-3, atol=3e-5,
                err_msg=f"{arch}: grad mismatch at {k}")
        print(f"PASS models/{arch} (loss {l1:.4f})")


CASES["models"] = case_models


def case_hlo_structure():
    """Paper SS3.2.1 visible in the lowering: per-block bucketing MERGES
    per-parameter all-gathers/reduce-scatters (counted in stablehlo, which
    preserves program structure; scan bodies count once)."""
    import re
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    def lower_text(bucket_mode, reorder):
        cfg, model = get_arch("qwen3_1_7b", smoke=True)
        dcfg = fp32_cfg(("data", "model"), (4, 2), ("data",),
                        bucket_mode=bucket_mode, reorder=reorder)
        storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "targets": jnp.zeros((8, 32), jnp.int32),
                 "valid": jnp.ones((8, 32))}
        step = RT.make_loss_step(model, dcfg)
        specs = RT.model_storage_specs(model, dcfg)
        fn, _ = RT.wrap_step(model, dcfg, ShapeConfig("t", 32, 8, "train"),
                             step, (P(), specs))
        return fn.lower(storage, batch).as_text()

    def count(txt, op):
        return len(re.findall(rf"stablehlo\.{op}\b", txt))

    none = lower_text("none", False)
    block = lower_text("block", False)
    n_ag, b_ag = count(none, "all_gather"), count(block, "all_gather")
    n_rs, b_rs = count(none, "reduce_scatter"), count(block, "reduce_scatter")
    assert b_ag < n_ag, (n_ag, b_ag)
    assert b_rs <= n_rs, (n_rs, b_rs)
    auto = lower_text("auto", True)
    assert count(auto, "all_gather") > 0
    print(f"PASS hlo_structure (AG {n_ag}->{b_ag}, RS {n_rs}->{b_rs})")


CASES["hlo_structure"] = case_hlo_structure



# --------------------------------------------------------------------------
# Pipeline parallelism: GPipe / 1F1B x SimpleFSDP x TP under a
# (pipe, data, model) mesh — paper SS4's composability, exact fp32 parity.
# --------------------------------------------------------------------------
PD, PH = 8, 16    # pipeline-stage model dim / hidden dim


def tp_stage_metas():
    """Every param TP-sharded: all cross-rank gradient flow goes through
    explicit collectives with exact transposes (all_gather <-> psum_scatter,
    ppermute <-> reverse ppermute), so pp x dp x tp parity is exact on any
    jax version (no reliance on vma replication-transpose psums)."""
    return {
        "w1": ParamMeta("w1", (PD, PH), tp_dim=1),
        "b": ParamMeta("b", (PH,), tp_dim=0),
        "w2": ParamMeta("w2", (PH, PD), tp_dim=0),
    }


def init_tp_stage(key):
    ks = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(ks[0], (PD, PH)) * 0.3,
        "b": jax.random.normal(ks[1], (PH,)) * 0.1,
        "w2": jax.random.normal(ks[2], (PH, PD)) * 0.3,
    }


def tp_stage_dense(p, x):
    h = jnp.tanh(x @ p["w1"]) + p["b"]
    return x + h @ p["w2"]


def tp_stage_local(p, x, cfg: DistConfig):
    """SP-style TP stage: x arrives batch-sharded over (data, model); the
    microbatch is all-gathered over TP for the sharded-H matmuls and the
    partial output is reduce-scattered back to the batch shard."""
    if cfg.tp_size > 1:
        xg = lax.all_gather(x, cfg.tp_axis, axis=0, tiled=True)
    else:
        xg = x
    h = jnp.tanh(xg @ p["w1"]) + p["b"]
    o = h @ p["w2"]
    if cfg.tp_size > 1:
        o = lax.psum_scatter(o, cfg.tp_axis, scatter_dimension=0, tiled=True)
    return x + o


def rep_stage_metas():
    """Mixed TP-sharded + replicated params (two vma bucket classes); run
    on tp=1 meshes where replicated-param grads are exact everywhere."""
    return {
        "w1": ParamMeta("w1", (PD, PH), tp_dim=1),
        "b": ParamMeta("b", (PH,), tp_dim=0),
        "g": ParamMeta("g", (1,), tp_dim=None),
        "w2": ParamMeta("w2", (PH, PD), tp_dim=0),
        "scale": ParamMeta("scale", (PD,), tp_dim=None),
    }


def init_rep_stage(key):
    ks = jax.random.split(key, 5)
    return {
        "w1": jax.random.normal(ks[0], (PD, PH)) * 0.3,
        "b": jax.random.normal(ks[1], (PH,)) * 0.1,
        "g": jnp.ones((1,)) * 0.7,
        "w2": jax.random.normal(ks[2], (PH, PD)) * 0.3,
        "scale": 1.0 + jax.random.normal(ks[3], (PD,)) * 0.1,
    }


def rep_stage_dense(p, x):
    h = jnp.tanh(x @ p["w1"]) * p["g"][0] + p["b"]
    return x + (h @ p["w2"]) * p["scale"]


def run_pipeline_case(cfg: DistConfig, plan, schedule: str, metas, init_fn,
                      dense_fn, local_fn, tag: str):
    """One pp x dp x tp configuration vs the single-device dense reference.

    Batch sharding spans (data, model) [SP]; grads come back under the
    repo's per-device-mean convention: param grads are tp x dense, dxs is
    (dp*tp) x dense (cf. run_stack_case's dp scaling).
    """
    from repro.core.pipeline import fsdp_stage_fn, pipeline_grads

    mesh = make_mesh(cfg)
    S, M, B = cfg.pp_size, 4, 8
    tp, dp = cfg.tp_size, cfg.dp_total
    stage_params = [init_fn(jax.random.PRNGKey(100 + s)) for s in range(S)]
    xs = jax.random.normal(jax.random.PRNGKey(9), (M, B, PD))

    # dense reference ------------------------------------------------------
    def dense_loss(ps, xs):
        y = xs
        for p in ps:
            y = dense_fn(p, y)
        return jnp.mean(y ** 2)

    ref_loss = dense_loss(stage_params, xs)
    ref_grads, ref_dxs = jax.grad(dense_loss, argnums=(0, 1))(
        stage_params, xs)

    # pipelined + FSDP + TP ------------------------------------------------
    # stage s's params live on pipe rank s, each ZeRO-3 sharded over 'data'
    # (and TP-indexed): storage (S, storage...) per leaf.
    storage = {
        k: jnp.stack([to_storage(stage_params[s][k], metas[k], cfg)
                      for s in range(S)])
        for k in metas
    }
    specs = {k: metas[k].pipe_stacked_storage_spec(cfg) for k in metas}
    batch_axes = ("data", "model") if tp > 1 else ("data",)
    xs_spec = P(None, batch_axes)
    nonpipe = tuple(a for a in cfg.mesh_axes if a != cfg.pp_axis)

    def loss_fn(y):
        return jnp.mean(y ** 2) / M

    stage = fsdp_stage_fn(lambda p, x: local_fn(p, x, cfg), metas, cfg, plan)

    def step(storage, xs):
        local = jax.tree.map(lambda a: a[0], storage)  # this rank's stage
        loss, grads, dxs = pipeline_grads(stage, local, xs, loss_fn, cfg,
                                          schedule)
        loss = lax.pmean(loss, nonpipe)
        grads = jax.tree.map(lambda g: g[None], grads)
        return loss, grads, dxs

    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(specs, xs_spec),
        out_specs=(P(), specs, xs_spec), check_vma=False))
    loss, grads, dxs = fn(storage, xs)

    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5,
                               err_msg=f"{tag}: loss mismatch")
    np.testing.assert_allclose(
        np.asarray(dxs) / (dp * tp), np.asarray(ref_dxs),
        rtol=2e-4, atol=2e-6, err_msg=f"{tag}: dxs mismatch")
    for k in metas:
        got = jnp.stack([from_storage(grads[k][s], metas[k], cfg)
                         for s in range(S)]) / tp
        want = jnp.stack([ref_grads[s][k] for s in range(S)])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-6,
                                   err_msg=f"{tag}: grad mismatch {k}")
    print(f"PASS {tag}")


PIPE_MESHES = {
    # pipe OUTERMOST (core/pipeline.py layout convention)
    "pp2_dp2_tp2": (("pipe", "data", "model"), (2, 2, 2)),
    "pp4_dp2": (("pipe", "data", "model"), (4, 2, 1)),
}


def case_pipeline():
    """GPipe and 1F1B over a (pipe, data, model) mesh with FSDP bucket
    gathers active inside each pipelined stage: losses and gradients match
    the single-device dense reference exactly in fp32 across bucket modes."""
    for mesh_name, (axes, shape) in PIPE_MESHES.items():
        tp = shape[axes.index("model")]
        if tp > 1:
            metas_fn, init_fn, dense_fn, local_fn = (
                tp_stage_metas, init_tp_stage, tp_stage_dense,
                tp_stage_local)
        else:
            metas_fn, init_fn, dense_fn, local_fn = (
                rep_stage_metas, init_rep_stage, rep_stage_dense,
                lambda p, x, cfg: rep_stage_dense(p, x))
        metas = metas_fn()
        plans = {"block": whole_block_plan(metas),
                 "none": per_param_plan(metas)}
        if tp == 1:
            plans["custom2"] = BucketPlan((("w1", "b", "g"),
                                           ("w2", "scale")))
        for schedule in ("gpipe", "1f1b"):
            for plan_name, plan in plans.items():
                cfg = fp32_cfg(axes, shape, ("data",), pp_axis="pipe",
                               pp_schedule=schedule)
                run_pipeline_case(
                    cfg, plan, schedule, metas, init_fn, dense_fn, local_fn,
                    f"pipeline/{mesh_name}/{schedule}/bucket={plan_name}")
    print("PASS pipeline (GPipe+1F1B x FSDP x TP, exact grads)")


CASES["pipeline"] = case_pipeline


# --------------------------------------------------------------------------
# The unified Trainer path (core/api.parallelize): full-LM stage partition.
# --------------------------------------------------------------------------
def _fp32_pp(schedule: str, microbatches: int = 2) -> DistConfig:
    return fp32_cfg(("pipe", "data", "model"), (2, 4, 1), ("data",),
                    pp_axis="pipe", pp_schedule=schedule,
                    pp_microbatches=microbatches)


def _synth_batch(model, shape, dcfg, vocab, valid_ones=True):
    from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch

    ds = SyntheticC4(DataConfig(vocab=vocab, seq_len=shape.seq_len,
                                global_batch=shape.global_batch))
    batch = adapt_batch(ds.batch(0), model.input_specs(shape, dcfg), 0)
    if valid_ones and "valid" in batch:
        # equal per-microbatch token counts: the microbatched mean-of-means
        # then equals the whole-batch mean exactly
        batch["valid"] = np.ones_like(batch["valid"])
    return batch


def case_trainer_pipeline():
    """Exact parity of the unified `parallelize()` path: the SAME model,
    params and batch through (a) the whole-model pp=1 loss/grad step and
    (b) the staged GPipe/1F1B pipeline at pp=2 — losses and every assembled
    full gradient must agree (tp=1, so this case is exact on every jax
    version; the stage partition covers untied heads, tied/replicated
    embeddings, and the MoE aux channel)."""
    from repro.core.api import parallelize
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch_for_pp

    for arch in ("deepseek_coder_33b", "qwen3_1_7b", "qwen2_moe_a2_7b"):
        cfg, model = get_arch_for_pp(arch, n_stages=2)
        shape = ShapeConfig("t", 32, 8, "train")
        d1 = fp32_cfg(("data", "model"), (4, 1), ("data",))
        batch = _synth_batch(model, shape, d1, cfg.vocab)
        full = model.init_full(jax.random.PRNGKey(0), d1)

        metas1 = model.metas(d1)
        st1 = {k: RT.tree_to_storage(full[k], metas1[k], d1) for k in full}
        par1 = parallelize(model, d1, shape)
        l1, g1 = par1.loss_step()(st1, batch)
        g1full = {k: RT.tree_from_storage(g1[k], metas1[k], d1) for k in g1}
        flat1 = {jax.tree_util.keystr(p): v for p, v in
                 jax.tree_util.tree_flatten_with_path(g1full)[0]}

        for schedule in ("gpipe", "1f1b"):
            dp = _fp32_pp(schedule)
            parp = parallelize(model, dp, shape)
            metasp = model.metas(dp)
            stp = parp.stage_storage(
                {k: RT.tree_to_storage(full[k], metasp[k], dp)
                 for k in full})
            lp, gp = parp.loss_step()(stp, batch)
            gplain = parp.unstage_storage(
                jax.tree.map(np.asarray, gp))
            gpfull = {k: RT.tree_from_storage(gplain[k], metasp[k], dp)
                      for k in gplain}
            flatp = {jax.tree_util.keystr(p): v for p, v in
                     jax.tree_util.tree_flatten_with_path(gpfull)[0]}
            tag = f"trainer_pipeline/{arch}/{schedule}"
            np.testing.assert_allclose(float(lp), float(l1), rtol=2e-5,
                                       err_msg=f"{tag}: loss mismatch")
            assert set(flatp) == set(flat1), f"{tag}: grad tree mismatch"
            for k, want in flat1.items():
                np.testing.assert_allclose(
                    np.asarray(flatp[k]), np.asarray(want),
                    rtol=3e-4, atol=3e-6,
                    err_msg=f"{tag}: grad mismatch at {k}")
            print(f"PASS {tag} (loss {float(lp):.4f})")

    # one TRAIN step through the replicated-embedding arch: the pipe-axis
    # grad psum + the deduplicated grad-norm must reproduce the baseline
    # metrics and the updated weights
    from repro.optim.adamw import AdamWConfig, init_opt_state

    cfg, model = get_arch_for_pp("qwen3_1_7b", n_stages=2)
    shape = ShapeConfig("t", 32, 8, "train")
    d1 = fp32_cfg(("data", "model"), (4, 1), ("data",))
    batch = _synth_batch(model, shape, d1, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), d1)
    metas1 = model.metas(d1)
    st1 = {k: RT.tree_to_storage(full[k], metas1[k], d1) for k in full}
    par1 = parallelize(model, d1, shape)
    fn1 = par1.train_step(AdamWConfig(lr=1e-3), donate=False)
    new1, _, m1 = fn1(st1, init_opt_state(st1), batch)

    dp = _fp32_pp("1f1b")
    parp = parallelize(model, dp, shape)
    metasp = model.metas(dp)
    stp = parp.stage_storage(
        {k: RT.tree_to_storage(full[k], metasp[k], dp) for k in full})
    fnp = parp.train_step(AdamWConfig(lr=1e-3), donate=False)
    newp, _, mp = fnp(stp, init_opt_state(stp), batch)
    np.testing.assert_allclose(float(mp["loss"]), float(m1["loss"]),
                               rtol=2e-5, err_msg="train step loss")
    np.testing.assert_allclose(float(mp["grad_norm"]),
                               float(m1["grad_norm"]), rtol=2e-4,
                               err_msg="train step grad_norm")
    new_plain = parp.unstage_storage(jax.tree.map(np.asarray, newp))
    for k in new1:
        a = {jax.tree_util.keystr(p): v for p, v in
             jax.tree_util.tree_flatten_with_path(new_plain[k])[0]}
        b = {jax.tree_util.keystr(p): v for p, v in
             jax.tree_util.tree_flatten_with_path(
                 jax.tree.map(np.asarray, new1[k]))[0]}
        for kk in b:
            np.testing.assert_allclose(
                a[kk], b[kk], rtol=2e-4, atol=1e-6,
                err_msg=f"updated params mismatch {k}{kk}")
    print("PASS trainer_pipeline/train_step (loss+gnorm+updated weights)")


CASES["trainer_pipeline"] = case_trainer_pipeline


# --------------------------------------------------------------------------
# PR-6 schedules: interleaved 1F1B (virtual stages) + zero-bubble W-split.
# --------------------------------------------------------------------------
def case_pipeline_v2():
    """Exact parity of the NEW table-driven schedules through
    `parallelize()`: at pp2 x dp4, `interleaved` (V=2 virtual stage chunks
    per rank) and `zb` (W-split zero-bubble) must reproduce the pp=1 losses
    and every assembled full gradient for a dense and an MoE arch, and the
    zb AdamW step must reproduce the pp=1 updated weights (tp=1, explicit
    collectives only, so exact on every jax version).  Also covers zamba2's
    UNEVEN superblock partition (stage_layers=(3,5), slots zero-padded to
    6): two chained train steps at pp=2 must track pp=1 — step 2 only
    agrees if the padded slots stayed exact identities through step 1's
    optimizer update — and the padded rows are asserted still exactly 0."""
    import dataclasses as _dc

    from repro.core.api import parallelize
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import build_model, get_arch
    from repro.optim.adamw import AdamWConfig, init_opt_state

    def _flat(tree):
        return {jax.tree_util.keystr(p): np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(tree)[0]}

    shape = ShapeConfig("t", 32, 8, "train")
    d1 = fp32_cfg(("data", "model"), (4, 1), ("data",))

    for arch in ("qwen3_1_7b", "qwen2_moe_a2_7b"):
        cfg, _ = get_arch(arch, smoke=True)
        cfg = _dc.replace(cfg, n_layers=4)     # Lps=2 -> V=2 chunks
        model = build_model(cfg)
        batch = _synth_batch(model, shape, d1, cfg.vocab)
        full = model.init_full(jax.random.PRNGKey(0), d1)
        metas1 = model.metas(d1)
        st1 = {k: RT.tree_to_storage(full[k], metas1[k], d1) for k in full}
        l1, g1 = parallelize(model, d1, shape).loss_step()(st1, batch)
        flat1 = _flat({k: RT.tree_from_storage(g1[k], metas1[k], d1)
                       for k in g1})

        for schedule, virtual in (("zb", 0), ("interleaved", 2)):
            dp = _fp32_pp(schedule).with_(pp_virtual=virtual)
            parp = parallelize(model, dp, shape)
            assert parp.plan.pp_schedule == schedule, parp.plan.pp_schedule
            if schedule == "interleaved":
                assert parp.plan.pp_virtual == 2, parp.plan.pp_virtual
            metasp = model.metas(dp)
            stp = parp.stage_storage(
                {k: RT.tree_to_storage(full[k], metasp[k], dp)
                 for k in full})
            lp, gp = parp.loss_step()(stp, batch)
            gplain = parp.unstage_storage(jax.tree.map(np.asarray, gp))
            flatp = _flat({k: RT.tree_from_storage(gplain[k], metasp[k], dp)
                           for k in gplain})
            tag = f"pipeline_v2/{arch}/{schedule}"
            np.testing.assert_allclose(float(lp), float(l1), rtol=2e-5,
                                       err_msg=f"{tag}: loss mismatch")
            assert set(flatp) == set(flat1), f"{tag}: grad tree mismatch"
            for k, want in flat1.items():
                np.testing.assert_allclose(
                    flatp[k], want, rtol=3e-4, atol=3e-6,
                    err_msg=f"{tag}: grad mismatch at {k}")
            print(f"PASS {tag} (loss {float(lp):.4f})")

        if arch == "qwen3_1_7b":       # one zb AdamW step vs pp=1
            fn1 = parallelize(model, d1, shape).train_step(
                AdamWConfig(lr=1e-3), donate=False)
            new1, _, m1 = fn1(st1, init_opt_state(st1), batch)
            dp = _fp32_pp("zb")
            parp = parallelize(model, dp, shape)
            metasp = model.metas(dp)
            stp = parp.stage_storage(
                {k: RT.tree_to_storage(full[k], metasp[k], dp)
                 for k in full})
            fnp = parp.train_step(AdamWConfig(lr=1e-3), donate=False)
            newp, _, mp = fnp(stp, init_opt_state(stp), batch)
            np.testing.assert_allclose(float(mp["loss"]), float(m1["loss"]),
                                       rtol=2e-5, err_msg="zb step loss")
            np.testing.assert_allclose(
                float(mp["grad_norm"]), float(m1["grad_norm"]), rtol=2e-4,
                err_msg="zb step grad_norm")
            a = _flat(parp.unstage_storage(jax.tree.map(np.asarray, newp)))
            b = _flat(jax.tree.map(np.asarray, new1))
            for k in b:
                np.testing.assert_allclose(
                    a[k], b[k], rtol=2e-4, atol=1e-5,
                    err_msg=f"zb updated params mismatch at {k}")
            print("PASS pipeline_v2/qwen3_1_7b/zb_train_step")

    # zamba2's uneven stages: (3, 5) real layers zero-padded to 6-row slots
    cfg, model = get_arch("zamba2_1_2b", smoke=True)
    spec = model.stage_spec(2)
    assert spec.stage_layers == (3, 5), spec.stage_layers
    assert spec.layers_per_stage == 6, spec.layers_per_stage
    batch = _synth_batch(model, shape, d1, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), d1)
    metas1 = model.metas(d1)
    st1 = {k: RT.tree_to_storage(full[k], metas1[k], d1) for k in full}
    fn1 = parallelize(model, d1, shape).train_step(
        AdamWConfig(lr=1e-3), donate=False)
    opt1 = init_opt_state(st1)
    new1, opt1, m1a = fn1(st1, opt1, batch)
    new1, _, m1b = fn1(new1, opt1, batch)

    dp = _fp32_pp("1f1b")
    parp = parallelize(model, dp, shape)
    metasp = model.metas(dp)
    stp = parp.stage_storage(
        {k: RT.tree_to_storage(full[k], metasp[k], dp) for k in full})
    fnp = parp.train_step(AdamWConfig(lr=1e-3), donate=False)
    optp = init_opt_state(stp)
    newp, optp, mpa = fnp(stp, optp, batch)
    newp, _, mpb = fnp(newp, optp, batch)
    np.testing.assert_allclose(float(mpa["loss"]), float(m1a["loss"]),
                               rtol=2e-5, err_msg="zamba2 step-1 loss")
    np.testing.assert_allclose(float(mpb["loss"]), float(m1b["loss"]),
                               rtol=2e-4, err_msg="zamba2 step-2 loss")
    # padded rows (slot 0 holds 3 real layers of 6) must still be EXACT
    # zeros after two optimizer steps — the identity-slot invariant
    for leaf in jax.tree.leaves(jax.tree.map(np.asarray,
                                             newp[spec.pipelined])):
        pad = leaf[0, spec.stage_layers[0]:]
        assert not np.any(pad), "zamba2 padded slot drifted from zero"
    a = _flat(parp.unstage_storage(jax.tree.map(np.asarray, newp)))
    b = _flat(jax.tree.map(np.asarray, new1))
    for k in b:
        np.testing.assert_allclose(
            a[k], b[k], rtol=5e-4, atol=3e-5,
            err_msg=f"zamba2 2-step params mismatch at {k}")
    print("PASS pipeline_v2/zamba2_1_2b/uneven_stages (2 chained steps)")

    # regression: stage-0's `stage_pre` (the embedding) is HOISTED out of
    # the slot loop — per step build it traces once inside the lax.map
    # over microbatches (+1 for the hoisted-vjp replay), NOT once per
    # pipeline slot (2(M+S-1) slots would each retrace it before the fix)
    calls = []
    orig_pre = model.stage_pre

    def counting_pre(*a, **kw):
        calls.append(1)
        return orig_pre(*a, **kw)

    model.stage_pre = counting_pre
    try:
        par2 = parallelize(model, dp, shape)
        jax.eval_shape(par2.loss_step(), stp, batch)
    finally:
        model.stage_pre = orig_pre
    n_slots = 2 * (dp.pp_microbatches + dp.pp_size - 1)
    assert 1 <= len(calls) <= 2 < n_slots, \
        f"stage_pre traced {len(calls)}x per step (slots={n_slots})"
    print(f"PASS pipeline_v2/stage_pre_hoist (traced {len(calls)}x, "
          f"{n_slots} slots)")


CASES["pipeline_v2"] = case_pipeline_v2


def case_remat_vector():
    """Memory subsystem parity (core/memory): per-segment remat policy
    vectors — including a budget-resolved auto plan — produce EXACTLY the
    same losses and assembled full gradients as the whole-block policy at
    pp2 x dp2 (tp=1, exact on every jax version).  Covers both stack paths:
    the segmented-vanilla per-segment checkpoint chain and the prefetch
    schedule's residency wraps."""
    from repro.core.api import parallelize
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch_for_pp

    cfg, model = get_arch_for_pp("qwen3_1_7b", n_stages=2)
    shape = ShapeConfig("t", 32, 8, "train")
    dp = fp32_cfg(("pipe", "data", "model"), (2, 4, 1), ("data",),
                  pp_axis="pipe", pp_schedule="1f1b", pp_microbatches=2)
    batch = _synth_batch(model, shape, dp, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), dp)
    metas = model.metas(dp)

    def run(dcfg):
        par = parallelize(model, dcfg, shape)
        st = par.stage_storage(
            {k: RT.tree_to_storage(full[k], metas[k], dcfg) for k in full})
        loss, grads = par.loss_step()(st, batch)
        plain = par.unstage_storage(jax.tree.map(np.asarray, grads))
        gfull = {k: RT.tree_from_storage(plain[k], metas[k], dcfg)
                 for k in plain}
        flat = {jax.tree_util.keystr(p): np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(gfull)[0]}
        return float(loss), flat, par.plan

    ref_l, ref_g, _ = run(dp)                      # whole-block fsdp_only
    variants = [
        ("vector/vanilla", dp.with_(reorder=False,
                                    remat="attn=full,mlp=fsdp_only")),
        ("vector/prefetch", dp.with_(remat="attn=full,mlp=save_dots")),
        ("auto_budget", dp.with_(remat="auto:8")),
    ]
    for tag, dcfg in variants:
        loss, grads, plan = run(dcfg)
        if tag == "auto_budget":
            assert plan.memory is not None \
                and plan.memory.peak <= plan.memory.budget_bytes
        np.testing.assert_allclose(loss, ref_l, rtol=2e-5,
                                   err_msg=f"remat_vector/{tag}: loss")
        assert set(grads) == set(ref_g), f"remat_vector/{tag}: grad tree"
        for k, want in ref_g.items():
            np.testing.assert_allclose(
                grads[k], want, rtol=3e-4, atol=3e-6,
                err_msg=f"remat_vector/{tag}: grad mismatch at {k}")
        print(f"PASS remat_vector/{tag} (loss {loss:.4f})")


CASES["remat_vector"] = case_remat_vector


# --------------------------------------------------------------------------
# Context parallelism (core/context.py): zigzag seq sharding + ring
# attention on the ctx axis — cp2 training must reproduce the cp1 baseline
# exactly (explicit collectives only: bucket RS over data x ctx, reverse-
# ring ppermute — exact on every jax version, like `pipeline`).
# --------------------------------------------------------------------------
def case_context():
    """cp2 x dp2 == cp1 x dp4: losses, every assembled gradient, and one
    AdamW step, for a dense arch and gemma2 (sliding window + softcaps —
    the ring's masked-hop path); then the full 4-axis composition
    pp2 x dp2 x cp2 against the pp1 x dp4 baseline."""
    from repro.core import context as CX
    from repro.core.api import parallelize
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch, get_arch_for_pp
    from repro.optim.adamw import AdamWConfig, init_opt_state

    def cp_cfg(**kw):
        return fp32_cfg(("data", "ctx", "model"), (2, 2, 1),
                        ("data", "ctx"), cp_axis="ctx", **kw)

    def flat_grads(par, dcfg, metas, grads):
        plain = par.unstage_storage(jax.tree.map(np.asarray, grads))
        full = {k: RT.tree_from_storage(plain[k], metas[k], dcfg)
                for k in plain}
        return {jax.tree_util.keystr(p): np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(full)[0]}

    for arch in ("qwen3_1_7b", "gemma2_27b"):
        cfg, model = get_arch(arch, smoke=True)
        shape = ShapeConfig("t", 32, 8, "train")
        d_ref = fp32_cfg(("data", "model"), (4, 1), ("data",))
        d_cp = cp_cfg()
        batch = _synth_batch(model, shape, d_ref, cfg.vocab)
        full = model.init_full(jax.random.PRNGKey(0), d_ref)

        m_ref = model.metas(d_ref)
        st_ref = {k: RT.tree_to_storage(full[k], m_ref[k], d_ref)
                  for k in full}
        par_ref = parallelize(model, d_ref, shape)
        l_ref, g_ref = par_ref.loss_step()(st_ref, batch)
        f_ref = flat_grads(par_ref, d_ref, m_ref, g_ref)

        m_cp = model.metas(d_cp)
        st_cp = {k: RT.tree_to_storage(full[k], m_cp[k], d_cp)
                 for k in full}
        par_cp = parallelize(model, d_cp, shape)
        assert "cp=2(ring)" in par_cp.plan.describe()
        l_cp, g_cp = par_cp.loss_step()(
            st_cp, CX.zigzag_batch(batch, d_cp))
        f_cp = flat_grads(par_cp, d_cp, m_cp, g_cp)

        tag = f"context/{arch}/cp2_vs_cp1"
        np.testing.assert_allclose(float(l_cp), float(l_ref), rtol=2e-5,
                                   err_msg=f"{tag}: loss mismatch")
        assert set(f_cp) == set(f_ref), f"{tag}: grad tree mismatch"
        for k, want in f_ref.items():
            np.testing.assert_allclose(
                f_cp[k], want, rtol=3e-4, atol=3e-6,
                err_msg=f"{tag}: grad mismatch at {k}")
        print(f"PASS {tag} (loss {float(l_cp):.4f})")

    # one AdamW train step: cp2's metrics and updated weights reproduce the
    # baseline (grad-norm psums span the data x ctx FSDP domain)
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    d_ref = fp32_cfg(("data", "model"), (4, 1), ("data",))
    d_cp = cp_cfg()
    batch = _synth_batch(model, shape, d_ref, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), d_ref)

    def one_step(dcfg, b):
        metas = model.metas(dcfg)
        st = {k: RT.tree_to_storage(full[k], metas[k], dcfg) for k in full}
        par = parallelize(model, dcfg, shape)
        fn = par.train_step(AdamWConfig(lr=1e-3), donate=False)
        new, _, met = fn(st, init_opt_state(st), b)
        new_full = {k: RT.tree_from_storage(jax.tree.map(np.asarray,
                                                         new[k]),
                                            metas[k], dcfg) for k in new}
        flat = {jax.tree_util.keystr(p): np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(new_full)[0]}
        return met, flat

    met1, w1 = one_step(d_ref, batch)
    met2, w2 = one_step(d_cp, CX.zigzag_batch(batch, d_cp))
    np.testing.assert_allclose(float(met2["loss"]), float(met1["loss"]),
                               rtol=2e-5, err_msg="context: step loss")
    np.testing.assert_allclose(float(met2["grad_norm"]),
                               float(met1["grad_norm"]), rtol=2e-4,
                               err_msg="context: step grad_norm")
    # atol 1e-5: AdamW's m/sqrt(v) amplifies fp reassociation noise on
    # near-zero-variance coordinates (same magnitude as trainer_pipeline)
    for k in w1:
        np.testing.assert_allclose(w2[k], w1[k], rtol=2e-4, atol=1e-5,
                                   err_msg=f"context: updated weights {k}")
    print("PASS context/train_step (loss+gnorm+updated weights)")

    # ---- the 4-axis composition: pp2 x dp2 x cp2 vs pp1 x dp4 ----
    cfg, model = get_arch_for_pp("qwen3_1_7b", n_stages=2)
    d1 = fp32_cfg(("data", "model"), (4, 1), ("data",))
    dpc = fp32_cfg(("pipe", "data", "ctx", "model"), (2, 2, 2, 1),
                   ("data", "ctx"), cp_axis="ctx", pp_axis="pipe",
                   pp_schedule="1f1b", pp_microbatches=2)
    batch = _synth_batch(model, shape, d1, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), d1)

    m1 = model.metas(d1)
    st1 = {k: RT.tree_to_storage(full[k], m1[k], d1) for k in full}
    par1 = parallelize(model, d1, shape)
    l1, g1 = par1.loss_step()(st1, batch)
    f1 = flat_grads(par1, d1, m1, g1)
    fn1 = par1.train_step(AdamWConfig(lr=1e-3), donate=False)
    new1, _, met1 = fn1(st1, init_opt_state(st1), batch)

    mp = model.metas(dpc)
    parp = parallelize(model, dpc, shape)
    assert parp.plan.pipelined and dpc.cp_size == 2
    stp = parp.stage_storage(
        {k: RT.tree_to_storage(full[k], mp[k], dpc) for k in full})
    bz = CX.zigzag_batch(batch, dpc)
    lp, gp = parp.loss_step()(stp, bz)
    fp_ = flat_grads(parp, dpc, mp, gp)
    tag = "context/pp2_dp2_cp2"
    np.testing.assert_allclose(float(lp), float(l1), rtol=2e-5,
                               err_msg=f"{tag}: loss mismatch")
    for k, want in f1.items():
        np.testing.assert_allclose(fp_[k], want, rtol=3e-4, atol=3e-6,
                                   err_msg=f"{tag}: grad mismatch at {k}")
    fnp = parp.train_step(AdamWConfig(lr=1e-3), donate=False)
    newp, _, metp = fnp(stp, init_opt_state(stp), bz)
    np.testing.assert_allclose(float(metp["loss"]), float(met1["loss"]),
                               rtol=2e-5, err_msg=f"{tag}: step loss")
    np.testing.assert_allclose(float(metp["grad_norm"]),
                               float(met1["grad_norm"]), rtol=2e-4,
                               err_msg=f"{tag}: step grad_norm")
    new_plain = parp.unstage_storage(jax.tree.map(np.asarray, newp))
    for k in new1:
        a = {jax.tree_util.keystr(p): v for p, v in
             jax.tree_util.tree_flatten_with_path(new_plain[k])[0]}
        b = {jax.tree_util.keystr(p): v for p, v in
             jax.tree_util.tree_flatten_with_path(
                 jax.tree.map(np.asarray, new1[k]))[0]}
        for kk in b:
            np.testing.assert_allclose(
                a[kk], b[kk], rtol=2e-4, atol=1e-5,
                err_msg=f"{tag}: updated params mismatch {k}{kk}")
    print(f"PASS {tag} (loss {float(lp):.4f}, AdamW step exact)")


CASES["context"] = case_context


# --------------------------------------------------------------------------
# Quantized collectives (kernels/quant + comm_precision): the wire codec is
# simulated by a local quantize->dequantize roundtrip before each collective,
# so dp4 runs every real code path (bucketed AG encode, RS encode, EF hop).
# --------------------------------------------------------------------------
def case_quant():
    """comm_precision end to end on a dp4 mesh (qwen3_1_7b smoke):
    (a) "bf16" is BIT-exact vs the default config over two chained AdamW
        steps (the identity codec must compile away);
    (b) fp8_ag / fp8 / fp8_ef / auto stay within documented EF-theory
        tolerance of the bf16 reference: losses rtol 5e-2, and per-
        coordinate updated-weight drift <= 4*lr*steps (AdamW's update is
        bounded by ~lr per step, so two quantized steps can disagree with
        the reference by at most ~2*lr per coordinate);
    (c) modes with an RS codec visibly perturb the weights (the codec is
        engaged, not silently skipped), and exactly the needs_ef modes
        carry a persistent error-feedback accumulator in opt_state."""
    from repro.core.api import parallelize
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.optim.adamw import AdamWConfig, init_opt_state

    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    d_ref = fp32_cfg(("data", "model"), (4, 1), ("data",))
    batch = _synth_batch(model, shape, d_ref, cfg.vocab)
    full = model.init_full(jax.random.PRNGKey(0), d_ref)

    def two_steps(dcfg):
        metas = model.metas(dcfg)
        st = {k: RT.tree_to_storage(full[k], metas[k], dcfg) for k in full}
        par = parallelize(model, dcfg, shape)
        fn = par.train_step(AdamWConfig(lr=1e-3), donate=False)
        opt = init_opt_state(st, dcfg)
        losses = []
        for _ in range(2):
            st, opt, met = fn(st, opt, batch)
            losses.append(float(met["loss"]))
        flat = {jax.tree_util.keystr(p): np.asarray(v) for p, v in
                jax.tree_util.tree_flatten_with_path(st)[0]}
        return losses, flat, opt

    l_ref, w_ref, opt_ref = two_steps(d_ref)
    assert "ef" not in opt_ref

    # ---- (a) explicit bf16 == default path, bit for bit ----
    l_bf, w_bf, opt_bf = two_steps(d_ref.with_(comm_precision="bf16"))
    assert l_bf == l_ref, f"quant/bf16: losses {l_bf} != {l_ref}"
    assert set(w_bf) == set(w_ref)
    for k in w_ref:
        assert np.array_equal(w_bf[k], w_ref[k]), \
            f"quant/bf16: storage leaf {k} not bit-exact"
    assert "ef" not in opt_bf
    print(f"PASS quant/bf16_bit_exact (losses {l_bf})")

    # ---- (b)+(c) quantized modes ----
    lr, steps = 1e-3, 2
    drift_bound = 4.0 * lr * steps
    for mode in ("fp8_ag", "fp8", "fp8_ef", "auto"):
        dq = d_ref.with_(comm_precision=mode)
        l_q, w_q, opt_q = two_steps(dq)
        tag = f"quant/{mode}"
        assert all(np.isfinite(l) for l in l_q), f"{tag}: {l_q}"
        np.testing.assert_allclose(l_q, l_ref, rtol=5e-2,
                                   err_msg=f"{tag}: loss drift")
        worst = max(float(np.max(np.abs(w_q[k] - w_ref[k])))
                    for k in w_ref)
        assert worst <= drift_bound, \
            f"{tag}: weight drift {worst:.2e} > {drift_bound:.2e}"
        if mode in ("fp8", "fp8_ef"):  # RS codec active -> SR perturbs
            assert any(not np.array_equal(w_q[k], w_ref[k])
                       for k in w_ref), f"{tag}: codec silently skipped"
        assert ("ef" in opt_q) == dq.needs_ef, f"{tag}: ef presence"
        if "ef" in opt_q:
            ef_mag = max(float(jnp.max(jnp.abs(l)))
                         for l in jax.tree.leaves(opt_q["ef"]))
            assert ef_mag > 0.0, f"{tag}: EF accumulator never updated"
        print(f"PASS {tag} (losses {l_q}, max drift {worst:.2e})")


CASES["quant"] = case_quant


TRAINER_SMOKE_ARCHS = {
    "trainer_smoke_a": ("deepseek_coder_33b", "phi3_medium_14b",
                        "gemma2_27b", "qwen3_1_7b", "llama3_8b"),
    "trainer_smoke_b": ("qwen2_moe_a2_7b", "qwen3_moe_30b_a3b",
                        "xlstm_1_3b", "seamless_m4t_large_v2",
                        "zamba2_1_2b", "internvl2_26b"),
}


def _case_trainer_smoke(archs):
    """Every registered arch trains 2 steps (incl. a staged checkpoint
    save) through the ONE Trainer on a pp2 x dp2 x tp2 mesh via
    parallelize() — the api_redesign acceptance gate. Smoke (finite,
    recorded losses), not parity: tp=2 grads are version-gated elsewhere."""
    import shutil
    import tempfile

    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch_for_pp
    from repro.optim.adamw import AdamWConfig
    from repro.train.trainer import Trainer, TrainerConfig

    for i, arch in enumerate(archs):
        cfg, model = get_arch_for_pp(arch, n_stages=2)
        seq = 64 if arch == "seamless_m4t_large_v2" else \
            40 if arch == "internvl2_26b" else 32
        shape = ShapeConfig("t", seq, 8, "train")
        dcfg = fp32_cfg(("pipe", "data", "model"), (2, 2, 2), ("data",),
                        pp_axis="pipe",
                        pp_schedule="1f1b" if i % 2 else "gpipe")
        ckpt_dir = tempfile.mkdtemp(prefix=f"pp_smoke_{arch}_")
        try:
            tcfg = TrainerConfig(total_steps=2, ckpt_every=2, log_every=1,
                                 warmup=1, ckpt_dir=ckpt_dir)
            tr = Trainer(model, dcfg, shape, AdamWConfig(lr=1e-3), tcfg)
            assert tr.plan.pipelined and tr.plan.stage.n_stages == 2
            _, _, hist = tr.run()
            assert hist and all(np.isfinite(h["loss"]) for h in hist), \
                f"{arch}: non-finite loss {hist}"
            assert tr.ckpt.latest_step() == 2, f"{arch}: no staged ckpt"
            print(f"PASS trainer_smoke/{arch} "
                  f"({dcfg.pp_schedule}, loss {hist[-1]['loss']:.4f})")
        finally:
            shutil.rmtree(ckpt_dir, ignore_errors=True)


CASES["trainer_smoke_a"] = \
    lambda: _case_trainer_smoke(TRAINER_SMOKE_ARCHS["trainer_smoke_a"])
CASES["trainer_smoke_b"] = \
    lambda: _case_trainer_smoke(TRAINER_SMOKE_ARCHS["trainer_smoke_b"])


# --------------------------------------------------------------------------
# Serving (core/serving): paged KV decode at tp2 x dp2 — pages sharded over
# the data axis, heads over model.  Two claims:
#   1. paged decode == dense-cache decode BITWISE on the same mesh (the
#      gather path reconstructs the identical logical (B, T, ...) view, so
#      the einsum/softmax work is token-for-token the same computation);
#   2. the tp2 x dp2 pipeline matches the tp1 x dp1 reference within the
#      harness's standard cross-mesh tolerance (psum reassociation makes
#      bitwise cross-mesh equality impossible even for dense prefill),
#      with identical greedy tokens at every step.
# Explicit-collective design (shard_map + check_vma=False): exact on
# jax 0.4 per the ROADMAP vma constraint.
# --------------------------------------------------------------------------
def case_serving():
    from repro.core.serving import pages as PG
    from repro.models import runtime as RT
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.train import serve as SV

    for arch, codec in (("qwen3_1_7b", None), ("qwen3_1_7b", "int8"),
                        ("gemma2_27b", None)):
        cfg, model = get_arch(arch, smoke=True)
        B, prompt, gen, page = 4, 12, 4, 4
        T = prompt + gen
        max_pages = T // page
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 3,
                                  cfg.vocab)
        padded = jnp.pad(toks, ((0, 0), (0, gen)), constant_values=3)

        results = {}
        for name, mesh_shape in (("1dev", (1, 1)), ("4dev", (2, 2))):
            dcfg = fp32_cfg(("data", "model"), mesh_shape, ("data",),
                            kv_cache_codec=codec)
            dp = dcfg.dp_total
            n_pages_local = (B // dp) * max_pages + 2
            storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
            params = SV.serve_params_from_storage(model, storage, dcfg)
            pf, mesh = SV.make_prefill_step(
                model, dcfg, ShapeConfig("p", T, B, "prefill"))
            dec, _ = SV.make_decode_step(
                model, dcfg, ShapeConfig("d", T, B, "decode"), mesh=mesh)
            pstep, _ = SV.make_paged_step(
                model, dcfg, ShapeConfig("d", T, B, "decode"), page=page,
                n_pages_local=n_pages_local, max_pages=max_pages,
                mesh=mesh)
            logits, cache = pf(params, {"tokens": padded})
            arena, table, pools = PG.dense_to_pages(
                cache, np.full((B,), prompt), page, n_pages_local,
                max_pages, dp_shards=dp)
            tbl = np.array(table)
            filled = -(-prompt // page)
            for b in range(B):
                ids = pools[b // (B // dp)].alloc(max_pages - filled)
                for j, pid in enumerate(ids):
                    tbl[b, filled + j] = pid
            table = jnp.asarray(tbl)
            tok_d = tok_p = jnp.argmax(logits, -1).astype(jnp.int32)
            step_logits, step_toks = [], []
            for i in range(gen):
                pos = jnp.full((B,), prompt + i, jnp.int32)
                ld, cache = dec(params, cache, tok_d, pos)
                lp, arena = pstep(params, arena, table, tok_p[:, None],
                                  pos[:, None])
                assert np.array_equal(np.asarray(ld), np.asarray(lp)), (
                    f"serving/{arch}/codec={codec}: paged != dense "
                    f"(bitwise) at step {i} on {name}")
                tok_d = jnp.argmax(ld, -1).astype(jnp.int32)
                tok_p = jnp.argmax(lp, -1).astype(jnp.int32)
                step_logits.append(np.asarray(lp))
                step_toks.append(np.asarray(tok_p))
            results[name] = (step_logits, step_toks)

        (l1, t1), (l4, t4) = results["1dev"], results["4dev"]
        for i in range(gen):
            np.testing.assert_allclose(
                l4[i], l1[i], rtol=2e-5, atol=1e-6,
                err_msg=f"serving/{arch}/codec={codec}: step {i} "
                        f"tp2xdp2 vs tp1xdp1 logits")
            assert np.array_equal(t4[i], t1[i]), (
                f"serving/{arch}/codec={codec}: step {i} greedy tokens "
                f"diverged across meshes")
        print(f"PASS serving/{arch}/codec={codec} "
              f"(paged==dense bitwise per mesh; tp2xdp2 ~ tp1xdp1)")


CASES["serving"] = case_serving


if __name__ == "__main__":
    names = sys.argv[1:] or list(CASES)
    for name in names:
        CASES[name]()
    print("ALL OK")
