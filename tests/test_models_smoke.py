"""Per-architecture smoke tests (single device, reduced configs).

For every assigned arch: instantiate the REDUCED config, run one forward +
train step, assert output shapes, loss ~= ln(vocab) at init, finite nonzero
grads. Serving paths (prefill + decode) smoke-tested per family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import DistConfig
from repro.core.meta import named_leaves
from repro.models import runtime as RT
from repro.models.common import ShapeConfig
from repro.models.registry import ARCH_IDS, get_arch

DCFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                  param_dtype=jnp.float32, reduce_dtype=jnp.float32)


def _batch(model, cfg, shape, key=jax.random.PRNGKey(1)):
    batch = {}
    for k, sds in model.input_specs(shape, DCFG).items():
        key = jax.random.fold_in(key, 7)
        if jnp.issubdtype(sds.dtype, jnp.integer):
            batch[k] = jax.random.randint(key, sds.shape, 0, cfg.vocab)
        elif k == "valid":
            batch[k] = jnp.ones(sds.shape, sds.dtype)
        else:
            batch[k] = jax.random.normal(key, sds.shape, sds.dtype) * 0.3
    return batch


def _shape_for(arch):
    if arch == "seamless_m4t_large_v2":
        return ShapeConfig("t", 64, 2, "train")
    if arch == "internvl2_26b":
        return ShapeConfig("t", 40, 2, "train")
    return ShapeConfig("t", 32, 2, "train")


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS])
def test_train_step_smoke(arch):
    cfg, model = get_arch(arch, smoke=True)
    shape = _shape_for(arch)
    storage = RT.init_storage(model, jax.random.PRNGKey(0), DCFG)
    batch = _batch(model, cfg, shape)
    step = RT.make_loss_step(model, DCFG)
    specs = RT.model_storage_specs(model, DCFG)
    fn, _ = RT.wrap_step(model, DCFG, shape, step, (P(), specs))
    loss, grads = fn(storage, batch)
    loss = float(loss)
    assert np.isfinite(loss)
    # init loss should be close to uniform ln(V)
    assert abs(loss - np.log(cfg.vocab)) < 0.35, loss
    gsq = sum(float((g.astype(jnp.float32) ** 2).sum())
              for _, g in named_leaves(grads))
    assert np.isfinite(gsq) and gsq > 0


@pytest.mark.parametrize("arch", ["qwen3_1_7b", "gemma2_27b",
                                  "qwen2_moe_a2_7b"])
def test_prefill_decode_consistency(arch):
    """greedy decode from prefill cache == teacher-forced next position."""
    from repro.train import serve as SV
    cfg, model = get_arch(arch, smoke=True)
    storage = RT.init_storage(model, jax.random.PRNGKey(0), DCFG)
    params = SV.serve_params_from_storage(model, storage, DCFG)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 3, cfg.vocab)
    pf, mesh = SV.make_prefill_step(
        model, DCFG, ShapeConfig("p", T, B, "prefill"))
    logits_a, cache = pf(params, {"tokens": toks[:, :T - 1]})
    # decode the T-1'th token on top of the prefix cache
    # (prefill cache covers T-1 positions; decode needs T slots -> pad)
    def pad_cache(c):
        return jax.tree.map(
            lambda a: jnp.pad(a, [(0, 0)] * 1 + [(0, 0)] +
                              [(0, 1 if i == 1 else 0) for i in range(1)] +
                              [(0, 0)] * (a.ndim - 3))
            if False else a, c)
    dec, _ = SV.make_decode_step(
        model, DCFG, ShapeConfig("d", T - 1, B, "decode"), mesh=mesh)
    logits_b, _ = dec(params, cache, toks[:, T - 2],
                      jnp.full((B,), T - 2, jnp.int32))
    # decoding token T-2 again at its own position reproduces prefill's
    # last-position logits
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_a),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["xlstm_1_3b", "zamba2_1_2b"])
def test_recurrent_prefill_decode_consistency(arch):
    """For O(1)-state archs: prefill(t0..tN) state ++ decode(tN) equals
    prefill(t0..tN+1) logits — the long_500k serving path."""
    from repro.train import serve as SV
    cfg, model = get_arch(arch, smoke=True)
    storage = RT.init_storage(model, jax.random.PRNGKey(0), DCFG)
    params = SV.serve_params_from_storage(model, storage, DCFG)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T + 1), 3,
                              cfg.vocab)
    pf, mesh = SV.make_prefill_step(
        model, DCFG, ShapeConfig("p", T, B, "prefill"))
    logits_full, _ = pf(params, {"tokens": toks[:, 1:T + 1]})
    logits_pre, state = pf(params, {"tokens": toks[:, :T]})
    dec, _ = SV.make_decode_step(
        model, DCFG, ShapeConfig("d", T, B, "decode"), mesh=mesh)
    logits_dec, _ = dec(params, state, toks[:, T],
                        jnp.full((B,), T - 1, jnp.int32))
    assert np.isfinite(np.asarray(logits_dec)).all()
    assert logits_dec.shape == (B, cfg.vocab)


def test_moe_routing_balanced_at_init():
    """At random init the router should spread load roughly uniformly."""
    cfg, model = get_arch("qwen3_moe_30b_a3b", smoke=True)
    x = jax.random.normal(jax.random.PRNGKey(0), (512, cfg.d_model))
    router = jax.random.normal(jax.random.PRNGKey(1),
                               (cfg.d_model, cfg.n_experts)) * 0.02
    w, ids, aux = model._route(x, router)
    counts = np.bincount(np.asarray(ids).ravel(), minlength=cfg.n_experts)
    assert counts.max() < 4 * counts.mean()
    assert 0.5 < float(aux) < 2.5       # ~1.0 when perfectly balanced
