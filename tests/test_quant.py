"""Quantized collectives: the fp8/int8 wire codec (kernels/quant), the
error-feedback hop in the optimizer, and the precision-aware planner.

Round-trip property tests run the pure-jnp reference AND the Pallas kernel
in interpret mode (bit-identical by construction — both share ref.py's
chunk/scale/SR helpers and the multiply-by-reciprocal scale).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dist import (AUTO_PRECISIONS, COMM_PRECISIONS, DistConfig,
                             precision_codecs)
from repro.kernels.quant import ops as quant_ops
from repro.kernels.quant import ref as quant_ref

pytestmark = pytest.mark.quant

CODECS = ("fp8", "int8")
# odd chunk remainders (n % QCHUNK != 0), LANE-aligned buffers, and
# TP-squeezed storage shapes (leading (1, chunk) shard dim)
SHAPES = ((7,), (127,), (129,), (1024,), (1, 384), (3, 5, 7))


def _x(shape, seed=0, scale=3.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape,
                                     jnp.float32)


# ---------------------------------------------------------------------------
# codec round-trip properties (reference implementation)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("shape", SHAPES)
def test_roundtrip_error_bound(codec, shape):
    """Deterministic RTN error is bounded per chunk: int8 by half a step
    (absmax/254 plus scale-rounding slack), fp8(e4m3) by half the step at
    the top binade (16/448 = absmax/28, again plus slack for the fp32
    reciprocal scale)."""
    x = _x(shape, seed=hash((codec, shape)) % 1000)
    rt = quant_ref.roundtrip(x, codec, stochastic=False)
    assert rt.shape == x.shape and rt.dtype == x.dtype
    x2, n = quant_ref.chunk(x)
    r2, _ = quant_ref.chunk(rt)
    absmax = jnp.max(jnp.abs(x2), axis=1)
    err = jnp.max(jnp.abs(x2 - r2), axis=1)
    bound = absmax * ((1.02 / 254.0) if codec == "int8" else (1.1 / 28.0)) + 1e-7
    assert bool(jnp.all(err <= bound)), (codec, shape, err, bound)


@pytest.mark.parametrize("codec", CODECS)
def test_quantize_value_bounds(codec):
    """Wire values stay inside the codec's representable range and zero
    chunks survive exactly (the all-zero scale guard)."""
    x = _x((1024,), seed=5)
    q, scales = quant_ref.quantize(x, codec, stochastic=False)
    assert q.dtype == quant_ref.WIRE_DTYPE[codec]
    qf = jnp.abs(q.astype(jnp.float32))
    assert float(jnp.max(qf)) <= quant_ref.QMAX[codec]
    assert scales.dtype == jnp.float32 and bool(jnp.all(scales > 0))

    z = jnp.zeros((256,), jnp.float32)
    assert bool(jnp.all(quant_ref.roundtrip(z, codec, True) == 0))
    assert bool(jnp.all(quant_ref.roundtrip(z, codec, False) == 0))


@pytest.mark.parametrize("codec", CODECS)
def test_stochastic_rounding_unbiased(codec):
    """The SR encode's signed error is tiny relative to the signal (the
    hash dither centers it); per-element error still respects one step."""
    x = _x((1 << 14,), seed=9)
    rt = quant_ref.roundtrip(x, codec, stochastic=True)
    err = np.asarray(rt - x, np.float64)
    assert abs(err.mean()) <= 0.01 * float(jnp.mean(jnp.abs(x)))
    x2, _ = quant_ref.chunk(x)
    absmax = jnp.max(jnp.abs(x2), axis=1, keepdims=True)
    step = absmax / (127.0 if codec == "int8" else 14.0)
    r2, _ = quant_ref.chunk(rt)
    assert bool(jnp.all(jnp.abs(x2 - r2) <= step + 1e-7))


def test_roundtrip_preserves_dtype():
    for dt in (jnp.float32, jnp.bfloat16):
        x = _x((640,), seed=2).astype(dt)
        rt = quant_ref.roundtrip(x, "fp8", stochastic=False)
        assert rt.dtype == dt
    # codec None is the identity (bf16 wire)
    x = _x((64,))
    assert bool(jnp.all(quant_ref.roundtrip(x, None, False) == x))


# ---------------------------------------------------------------------------
# Pallas kernel parity (interpret mode on CPU)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", CODECS)
@pytest.mark.parametrize("stochastic", (False, True))
@pytest.mark.parametrize("shape", ((129,), (1024,), (1, 384)))
def test_pallas_matches_ref(codec, stochastic, shape):
    x = _x(shape, seed=hash((codec, stochastic)) % 1000)
    want = quant_ref.roundtrip(x, codec, stochastic=stochastic)
    got = quant_ops.roundtrip_pallas(x, codec, stochastic=stochastic,
                                     interpret=True)
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# error feedback: the compensated quantizer recovers the true gradient
# ---------------------------------------------------------------------------
def test_error_feedback_converges():
    """With a constant gradient, the EF-compensated quantized stream's
    running mean converges to the true gradient (residual stays bounded by
    one quantization step, so the average error decays as 1/T)."""
    from repro.optim.adamw import _error_feedback

    g = {"w": _x((512,), seed=11)}
    ef = {"w": jnp.zeros((512,), jnp.float32)}
    total = jnp.zeros((512,), jnp.float32)
    T = 50
    for _ in range(T):
        gq, ef = _error_feedback(g, ef)
        total = total + gq["w"]
    avg = total / T
    x2, _ = quant_ref.chunk(g["w"])
    step = float(jnp.max(jnp.abs(x2))) / 14.0
    assert float(jnp.max(jnp.abs(avg - g["w"]))) <= 2.0 * step / T + 1e-6
    # the residual itself never exceeds one step
    assert float(jnp.max(jnp.abs(ef["w"]))) <= step + 1e-6


def test_quantized_adamw_tracks_bf16():
    """~50 toy AdamW steps on a least-squares problem: the fp8_ef run's
    loss trajectory tracks the unquantized run within a loose tolerance
    (EF-theory: compensated quantization preserves convergence)."""
    from repro.core.compat import shard_map
    from repro.core.dist import make_mesh
    from repro.core.meta import ParamMeta, from_storage, to_storage
    from repro.optim.adamw import AdamWConfig, apply_adamw, init_opt_state

    D = 64
    w_true = _x((D,), seed=3, scale=1.0)
    X = _x((256, D), seed=4, scale=1.0)
    y = X @ w_true

    def run(comm_precision):
        cfg = DistConfig(
            mesh_axes=("data", "model"), mesh_shape=(1, 1),
            fsdp_axes=("data",), param_dtype=jnp.float32,
            reduce_dtype=jnp.float32, storage_dtype=jnp.float32,
            comm_precision=comm_precision)
        mesh = make_mesh(cfg)
        metas = {"w": ParamMeta("w", (D,), tp_dim=None)}
        st = {"w": to_storage(jnp.zeros((D,), jnp.float32),
                              metas["w"], cfg)}
        opt = init_opt_state(st, cfg)
        ocfg = AdamWConfig(lr=3e-2, weight_decay=0.0)

        def step(st, opt):
            def loss_of(s):
                w = from_storage(s["w"], metas["w"], cfg)
                return jnp.mean((X @ w - y) ** 2)

            loss, grads = jax.value_and_grad(loss_of)(st)
            new_p, new_opt, _ = apply_adamw(st, grads, opt, metas, cfg,
                                            ocfg, ocfg.lr)
            return new_p, new_opt, loss

        P = jax.sharding.PartitionSpec
        fn = jax.jit(shard_map(step, mesh=mesh, in_specs=P(),
                               out_specs=P(), check_vma=False))
        losses = []
        for _ in range(50):
            st, opt, l = fn(st, opt)
            losses.append(float(l))
        return losses, opt

    base, opt_b = run("bf16")
    quant, opt_q = run("fp8_ef")
    assert "ef" not in opt_b and "ef" in opt_q
    assert base[-1] < 0.1 * base[0]          # the problem actually trains
    assert quant[-1] < 0.1 * quant[0]
    # trajectory tracks within loose EF tolerance
    for b, q in zip(base, quant):
        assert abs(q - b) <= 0.2 * abs(b) + 1e-3, (b, q)


# ---------------------------------------------------------------------------
# wire pricing + precision plumbing
# ---------------------------------------------------------------------------
def test_wire_bytes_ratio():
    from repro.core.irgraph import wire_bytes

    n = 1 << 20
    bf16 = wire_bytes(n, 2)
    fp8 = wire_bytes(n, 2, "fp8")
    assert bf16 == 2 * n
    assert fp8 == n + 4 * (n // 128)
    assert fp8 / bf16 == pytest.approx(0.515625)
    # remainder chunks still pay a full scale
    assert wire_bytes(129, 2, "fp8") == 129 + 8


def test_precision_vocabulary():
    assert set(AUTO_PRECISIONS) <= set(COMM_PRECISIONS)
    assert precision_codecs("bf16") == (None, None)
    assert precision_codecs("fp8_ag") == ("fp8", None)
    assert precision_codecs("fp8") == ("fp8", "fp8")
    assert precision_codecs("fp8_ef") == ("fp8", "fp8")
    with pytest.raises(KeyError):
        precision_codecs("auto")      # must be resolved by the planner
    with pytest.raises(ValueError):
        DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                   fsdp_axes=("data",), comm_precision="int4")
    cfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                     fsdp_axes=("data",), comm_precision="auto")
    assert cfg.needs_ef
    assert not cfg.with_(comm_precision="fp8").needs_ef


def test_auto_planner_never_worse_than_bf16():
    """The joint partition x precision DP's objective is <= the all-bf16
    DP's on the same workload, and every chosen precision is in the auto
    lattice."""
    from repro.core.autowrap import auto_dp_plan, exposed_comm_time
    from repro.core.meta import ParamMeta

    metas = {f"w{i}": ParamMeta(f"w{i}", (256, 256), tp_dim=None)
             for i in range(6)}
    base = DistConfig(mesh_axes=("data", "model"), mesh_shape=(64, 1),
                      fsdp_axes=("data",), bucket_mode="auto_dp")
    r_bf = exposed_comm_time(auto_dp_plan(metas, base), metas, base)
    auto = base.with_(comm_precision="auto")
    plan = auto_dp_plan(metas, auto)
    r_auto = exposed_comm_time(plan, metas, auto)
    assert r_auto["exposed_s"] <= r_bf["exposed_s"] + 1e-12
    assert plan.precisions is not None
    assert set(plan.precisions) <= set(AUTO_PRECISIONS)
    # per-group resolution survives the runtime lookup path
    precs = plan.group_precisions(metas, auto)
    assert precs == list(plan.precisions)


def test_bucket_plan_precisions_split_at_segments():
    from repro.core.bucketing import BucketPlan, split_plan_at_segments
    from repro.core.meta import ParamMeta
    from repro.models.common import BlockSegments

    metas = {"a": ParamMeta("a", (128,), tp_dim=None),
             "b": ParamMeta("b", (128,), tp_dim=None)}
    plan = BucketPlan((("a", "b"),), precisions=("fp8_ef",))
    segs = BlockSegments(names=("s0", "s1"),
                         fns=(lambda *a: None, lambda *a: None),
                         param_globs=(("a",), ("b",)))
    out = split_plan_at_segments(plan, metas, segs)
    assert out.groups == (("a",), ("b",))
    assert out.precisions == ("fp8_ef", "fp8_ef")
