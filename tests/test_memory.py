"""Memory subsystem (core/memory): live-range simulator invariants, the
budgeted auto-SAC planner, remat-spec validation, runtime parity of
per-segment policy vectors, calibration against XLA, and the
BENCH_memory.json schema smoke.

Multi-device parity of per-segment remat vs whole-block remat at pp2 x dp2
lives in tests/dist_harness.py case `remat_vector`.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import memory as MEM
from repro.core.api import parallelize, plan_parallel
from repro.core.dist import DistConfig
from repro.core.remat import (POLICIES, parse_remat, parse_policy_vector,
                              resolve_segment_policies, whole_block_policy)
from repro.models.common import ShapeConfig
from repro.models.registry import ARCH_IDS, get_arch

pytestmark = pytest.mark.memory

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PROD = DistConfig(mesh_axes=("data", "model"), mesh_shape=(16, 16))
BSHAPE = (1, 4096)


def _small_cfg(**kw) -> DistConfig:
    return DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                      param_dtype=jnp.float32, storage_dtype=jnp.float32,
                      reduce_dtype=jnp.float32, **kw)


# ---------------------------------------------------------------------------
# remat spec grammar: one place, pointed errors, validated at plan time
# ---------------------------------------------------------------------------
def test_parse_remat_forms():
    assert parse_remat("fsdp_only") == ("fsdp_only", None)
    kind, budget = parse_remat("auto:12.5")
    assert kind == "auto" and budget == 12.5 * 1024**3
    assert parse_remat("attn=full,mlp=fsdp_only")[0] == "vector"
    assert parse_policy_vector("full,none") == ((None, "full"),
                                                (None, "none"))


@pytest.mark.parametrize("bad,msg", [
    ("auto", "needs an HBM budget"),
    ("auto:", "needs an HBM budget"),
    ("auto:abc", "not a number"),
    ("auto:0", "finite GiB value > 0"),
    ("auto:-3", "finite GiB value > 0"),
    ("auto:nan", "finite GiB value > 0"),
    ("auto:inf", "finite GiB value > 0"),
    ("bogus", "unknown remat policy"),
    ("attn=bogus,mlp=full", "unknown policy"),
    ("attn=full,fsdp_only", "mix of named"),
    ("full,,none", "empty entry"),
])
def test_parse_remat_pointed_errors(bad, msg):
    with pytest.raises(ValueError, match=msg):
        parse_remat(bad)


def test_malformed_remat_fails_at_plan_time_not_first_trace():
    """Satellite: plan_parallel rejects malformed strings once, pointedly."""
    _, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    for bad in ("auto:", "auto:x", "zzz"):
        with pytest.raises(ValueError):
            plan_parallel(model, _small_cfg(remat=bad), shape)
    # auto without a shape cannot size activations -> pointed, not cryptic
    with pytest.raises(ValueError, match="shape"):
        plan_parallel(model, _small_cfg(remat="auto:8"))


def test_resolve_segment_policies():
    assert resolve_segment_policies("full", ("attn", "mlp")) \
        == ("full", "full")
    assert resolve_segment_policies("attn=none,mlp=full",
                                    ("attn", "mlp")) == ("none", "full")
    assert resolve_segment_policies("none,full", ("attn", "mlp")) \
        == ("none", "full")
    with pytest.raises(ValueError, match="cover the block segments"):
        resolve_segment_policies("attn=none", ("attn", "mlp"))
    with pytest.raises(ValueError, match="3 entries for 2"):
        resolve_segment_policies("none,full,full", ("attn", "mlp"))
    with pytest.raises(ValueError, match="unresolved"):
        resolve_segment_policies("auto:8", ("attn", "mlp"))
    assert whole_block_policy("attn=none,mlp=full") == "full"
    assert whole_block_policy("save_dots") == "save_dots"
    # aggressiveness = residuals DROPPED: save_dots drops more than
    # fsdp_only, so the collapse must pick save_dots of the two
    assert whole_block_policy("attn=save_dots,mlp=fsdp_only") == "save_dots"
    assert whole_block_policy("attn=none,mlp=fsdp_only") == "fsdp_only"


# ---------------------------------------------------------------------------
# simulator invariants — every registered arch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_simulator_policy_monotonicity(arch):
    """peak(full) <= peak(save_dots) <= peak(fsdp_only) <= peak(none), on
    both stack paths (saved residuals on vanilla, backward recompute
    residency on the prefetch schedule)."""
    _, model = get_arch(arch)
    for reorder in (True, False):
        d = PROD.with_(reorder=reorder)
        peaks = {}
        for pol in ("full", "save_dots", "fsdp_only", "none"):
            bk = MEM.simulate_peak(model, d.with_(remat=pol), BSHAPE)
            assert len(bk) == 1 and bk[0].peak_bytes > 0
            peaks[pol] = bk[0].peak_bytes
        assert peaks["full"] <= peaks["save_dots"] \
            <= peaks["fsdp_only"] <= peaks["none"], (arch, reorder, peaks)


def test_simulator_pipeline_inflight_bounds():
    """GPipe holds M stacks, 1F1B min(M, S - s) — and the simulated 1F1B
    peak is never above GPipe's on any stage."""
    assert MEM.in_flight_microbatches(PROD.with_(pp_schedule="gpipe"),
                                      0, 4, 8) == 8
    assert MEM.in_flight_microbatches(PROD.with_(pp_schedule="1f1b"),
                                      0, 4, 8) == 4
    assert MEM.in_flight_microbatches(PROD.with_(pp_schedule="1f1b"),
                                      3, 4, 8) == 1

    from repro.models.registry import get_arch_for_pp
    _, model = get_arch_for_pp("deepseek_coder_33b", n_stages=2,
                               smoke=False)
    stage = model.stage_spec(2)
    d = PROD.with_(mesh_axes=("pipe", "data", "model"),
                   mesh_shape=(2, 8, 16), pp_axis="pipe")
    g = MEM.simulate_peak(model, d.with_(pp_schedule="gpipe"), BSHAPE,
                          stage=stage, microbatches=8)
    f = MEM.simulate_peak(model, d.with_(pp_schedule="1f1b"), BSHAPE,
                          stage=stage, microbatches=8)
    assert len(g) == 2 and len(f) == 2
    for gs, fs in zip(g, f):
        assert fs.peak_bytes <= gs.peak_bytes


def test_segment_prefetch_off_models_the_executed_collapse():
    """With cfg.segment_prefetch off the prefetch runtime collapses any
    vector to its most aggressive entry on one whole-layer segment — the
    simulator and planner must model THAT schedule, not the declared one."""
    _, model = get_arch("qwen3_1_7b")
    off = PROD.with_(segment_prefetch=False)
    # fixed vector: modeled as the collapsed policy ('full' beats 'none')
    bk = MEM.simulate_peak(model, off.with_(remat="attn=full,mlp=none"),
                           BSHAPE)
    ref = MEM.simulate_peak(model, off.with_(remat="full"), BSHAPE)
    assert bk[0].peak_bytes == ref[0].peak_bytes
    # auto: the search space collapses to uniform single-segment vectors
    mp = MEM.plan_memory(model, off.with_(remat="auto:8"),
                         batch_shape=BSHAPE)
    assert mp.segment_names == ("block",) and len(mp.policies) == 1
    # the vanilla path executes vectors regardless of segment_prefetch
    mpv = MEM.plan_memory(
        model, off.with_(reorder=False, remat="attn=full,mlp=none"),
        batch_shape=BSHAPE)
    assert mpv.policies == ("full", "none")


def test_simulator_offload_reduces_device_peak():
    _, model = get_arch("deepseek_coder_33b")
    base = MEM.simulate_peak(model, PROD, BSHAPE)[0]
    off = MEM.simulate_peak(model, PROD, BSHAPE, offload_opt=True)[0]
    assert off.peak_bytes < base.peak_bytes
    assert off.host_bytes > 0


# ---------------------------------------------------------------------------
# the budgeted planner
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_auto_budget_satisfied_every_arch(arch):
    """remat='auto:<GB>' produces plans whose modeled peak respects the
    budget on every registered arch (acceptance criterion)."""
    _, model = get_arch(arch)
    budget_gb = 8.0
    mp = MEM.plan_memory(model, PROD.with_(remat=f"auto:{budget_gb}"),
                         batch_shape=BSHAPE)
    assert mp.budget_bytes == budget_gb * 1024**3
    assert mp.peak <= mp.budget_bytes, mp.describe()
    assert all(p in POLICIES for p in mp.policies)
    # the resolved spec round-trips through the grammar
    resolve_segment_policies(
        mp.policy_spec,
        mp.segment_names if mp.segment_names != ("block",) else ())


def test_auto_infeasible_budget_raises_pointed():
    _, model = get_arch("deepseek_coder_33b")
    with pytest.raises(ValueError, match="no plan fits .* budget"):
        MEM.plan_memory(model, PROD.with_(remat="auto:0.01"),
                        batch_shape=BSHAPE)


def test_auto_nonuniform_beats_every_uniform_policy():
    """Acceptance: for at least one arch/budget the chosen per-segment
    vector is NON-uniform and strictly beats every uniform global policy on
    modeled recompute+exposure cost (infeasible uniforms count as +inf)."""
    found = None
    for arch in ("deepseek_coder_33b", "qwen3_moe_30b_a3b", "llama3_8b"):
        _, model = get_arch(arch)
        d = PROD.with_(reorder=False)   # vanilla: residuals swing on policy
        uni = {}
        for pol in POLICIES:
            mp = MEM.plan_memory(model, d.with_(remat=pol),
                                 batch_shape=BSHAPE)
            uni[pol] = (mp.peak, mp.cost_s)
        peaks = sorted(p for p, _ in uni.values())
        # budgets straddling the uniform peaks force mixing
        for i in range(len(peaks) - 1):
            budget = (peaks[i] + peaks[i + 1]) / 2 / 1024**3
            try:
                mp = MEM.plan_memory(
                    model, d.with_(remat=f"auto:{budget:.6f}"),
                    batch_shape=BSHAPE)
            except ValueError:
                continue
            if len(set(mp.policies)) > 1 and not mp.offload_opt_state \
                    and not mp.offload_residuals:
                for pol, (peak, cost) in uni.items():
                    if peak <= mp.budget_bytes:
                        assert mp.cost_s < cost, \
                            f"{arch}: {mp.policies} not beating {pol}"
                found = (arch, mp.policies, budget)
                break
        if found:
            break
    assert found, "no arch produced a winning non-uniform policy vector"


def test_auto_prefers_cheapest_when_budget_is_loose():
    _, model = get_arch("qwen3_1_7b")
    mp = MEM.plan_memory(model, PROD.with_(remat="auto:16"),
                         batch_shape=BSHAPE)
    assert set(mp.policies) == {"none"}        # zero recompute fits easily
    assert not mp.offload_opt_state and not mp.offload_residuals


# ---------------------------------------------------------------------------
# plan_parallel integration: the plan the runtime executes IS the plan
# ---------------------------------------------------------------------------
def test_plan_parallel_resolves_auto_into_exec_dcfg():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    plan = plan_parallel(model, _small_cfg(remat="auto:8"), shape)
    assert plan.memory is not None
    assert plan.remat == "auto:8"                      # user intent kept
    kind, _ = parse_remat(plan.exec_dcfg.remat)        # resolved for trace
    assert kind != "auto"
    assert plan.memory.peak <= 8 * 1024**3
    assert "mem[" in plan.describe()


def test_fixed_plan_records_memory_but_keeps_dcfg():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 8, "train")
    dcfg = _small_cfg()
    plan = plan_parallel(model, dcfg, shape)
    assert plan.memory is not None
    assert plan.memory.policy_spec == dcfg.remat
    assert plan.exec_dcfg == dcfg


def test_per_segment_vector_exact_parity_single_device():
    """Per-segment remat vs whole-block remat: same losses and grads to
    fp32 tolerance on both stack paths (the pp2 x dp2 twin lives in
    dist_harness `remat_vector`)."""
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    shape = ShapeConfig("t", 32, 4, "train")
    from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch
    ds = SyntheticC4(DataConfig(vocab=cfg.vocab, seq_len=32,
                                global_batch=4))
    base = _small_cfg()
    batch = adapt_batch(ds.batch(0), model.input_specs(shape, base), 0)

    def run(**kw):
        par = parallelize(model, _small_cfg(**kw), shape)
        storage = par.init_storage(jax.random.PRNGKey(0))
        return par.loss_step()(storage, batch)

    ref_l, ref_g = run(reorder=False, remat="fsdp_only")
    for kw in (dict(reorder=False, remat="attn=full,mlp=fsdp_only"),
               dict(reorder=False, remat="attn=none,mlp=save_dots"),
               dict(reorder=True, remat="attn=full,mlp=save_dots")):
        loss, grads = run(**kw)
        np.testing.assert_allclose(float(loss), float(ref_l), rtol=1e-6,
                                   err_msg=str(kw))
        for (pa, a), (_, b) in zip(
                jax.tree_util.tree_flatten_with_path(grads)[0],
                jax.tree_util.tree_flatten_with_path(ref_g)[0]):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-6,
                err_msg=f"{kw} {jax.tree_util.keystr(pa)}")


# ---------------------------------------------------------------------------
# calibration vs XLA on a 1-device block (launch/dryrun.harvest_memory_stats)
# ---------------------------------------------------------------------------
def test_memory_calibration_within_tolerance():
    from repro.launch.dryrun import harvest_memory_stats

    _, model = get_arch("qwen3_1_7b", smoke=True)
    ms = harvest_memory_stats(model, _small_cfg(), (2, 64))
    assert ms is not None, "1-device block memory harvest failed"
    ratio = ms.measured_bytes / ms.modeled_bytes
    # loose envelope: the analytic residency must be the right ORDER of
    # magnitude; act_scale carries the residual into the simulator clamped
    assert 0.1 <= ratio <= 10.0, ratio
    assert 0.25 <= ms.act_scale <= 4.0


def test_per_segment_harvest_feeds_simulator():
    from repro.launch.dryrun import harvest_block_stats

    _, model = get_arch("qwen3_1_7b", smoke=True)
    d = _small_cfg()
    bs = harvest_block_stats(model, d, (2, 64))
    assert bs is not None and bs.source == "measured"
    assert bs.seg_act_bytes and set(bs.seg_act_bytes) == {"attn", "mlp"}
    assert all(v > 0 for v in bs.seg_act_bytes.values())
    prof = MEM.build_block_profile(model.block_metas(d), d, bs,
                                   model.block_segments(d))
    names = {s.name: s for s in prof.segments}
    # the simulator consumes the MEASURED per-segment activation numbers
    for k, v in bs.seg_act_bytes.items():
        assert names[k].act_bytes == v


# ---------------------------------------------------------------------------
# BENCH_memory.json schema smoke (tier-1 artifact, like overlap/pipeline)
# ---------------------------------------------------------------------------
def test_bench_memory_json_schema(tmp_path):
    import json

    sys.path.insert(0, ROOT)
    try:
        from benchmarks import paper_tables as T
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "BENCH_memory.json")
    doc = T.memory_table(json_path=path, archs=("llama3_8b",))
    on_disk = json.load(open(path))
    assert on_disk == doc
    assert doc["schema"] == "bench_memory_v1"
    for arch, rec in doc["archs"].items():
        modes = rec["modes"]
        assert set(modes) == {"none", "save_dots", "fsdp_only", "full",
                              "auto"}
        # the paper's Table 3 ordering: no-AC > SAC > full-AC on memory...
        assert modes["none"]["peak_bytes"] >= modes["fsdp_only"]["peak_bytes"] \
            >= modes["full"]["peak_bytes"]
        # ...reversed on modeled step time (recompute costs time)
        assert modes["full"]["modeled_step_s"] \
            >= modes["none"]["modeled_step_s"]
        assert modes["auto"]["peak_bytes"] <= doc["budget_gb"] * 1024**3
        for row in modes.values():
            assert row["peak_bytes"] > 0 and row["modeled_step_s"] > 0


def test_checked_in_bench_memory_json_is_current_schema():
    import json

    path = os.path.join(ROOT, "benchmarks", "results", "BENCH_memory.json")
    assert os.path.exists(path), "run `python -m benchmarks.run mem --json`"
    doc = json.load(open(path))
    assert doc["schema"] == "bench_memory_v1"
    assert len(doc["archs"]) >= 3
