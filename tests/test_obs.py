"""Telemetry tests: trace emitter, metrics registry, drift monitor.

The load-bearing claim is the trace invariant: the comm-lane span time
NOT covered by a compute-lane span in the emitted Chrome-trace JSON
equals the planner's modeled `exposed_s` (asserted within the 1%
acceptance tolerance on the full pp2 x dp2 x cp2 layout — in practice it
matches to float precision, because the layout is constructed from the
same pooled cyclic windows `partition_exposure` scores).
"""

import json
import math

import jax.numpy as jnp
import pytest

from repro.core import irgraph
from repro.core.dist import DistConfig
from repro.core.obs import (PID_MODELED, TID_COMM, TID_COMPUTE, DriftMonitor,
                            MetricsRegistry, TraceBuilder, lane_spans,
                            modeled_step_time, nonoverlapped_comm_s,
                            pipeline_lanes, plan_trace, serving_lanes)
from repro.core.obs.trace import TID_PIPE_BASE
from repro.core.serving import (Router, plan_serve, run_virtual,
                                synthetic_trace)
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch, get_arch_for_pp

pytestmark = pytest.mark.obs

DCFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                  param_dtype=jnp.float32, reduce_dtype=jnp.float32)

# the acceptance layout: pipeline x data x context in one mesh
PP_DCFG = DistConfig(
    mesh_axes=("pipe", "data", "ctx", "model"), mesh_shape=(2, 2, 2, 1),
    fsdp_axes=("data", "ctx"), pp_axis="pipe", cp_axis="ctx",
    tp_axis="model", pp_schedule="1f1b",
    param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32)


@pytest.fixture(scope="module")
def pp_plan():
    from repro.core.api import plan_parallel
    cfg, model = get_arch_for_pp("llama3_8b", n_stages=2, smoke=True)
    shape = ShapeConfig("t", 64, 8, "train")
    return cfg, model, shape, plan_parallel(model, PP_DCFG, shape)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_registry_typed_metrics():
    reg = MetricsRegistry()
    reg.counter("a").inc(3)
    reg.counter("a").inc()
    assert reg.counter("a").value == 4.0
    g = reg.gauge("b")
    g.set(1.0)
    g.set(2.0)                       # ewma = 0.2*2 + 0.8*1 = 1.2
    assert g.value == 2.0 and g.ewma == pytest.approx(1.2)
    h = reg.histogram("c")
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and h.min == 0.0 and h.max == 99.0
    assert h.percentile(50) == pytest.approx(50.0, abs=1.0)
    assert h.percentile(99) == pytest.approx(98.0, abs=1.0)
    assert set(reg.names()) == {"a", "b", "c"} and "a" in reg


def test_registry_one_name_one_type():
    reg = MetricsRegistry()
    reg.counter("train/steps")
    with pytest.raises(TypeError, match="one name binds one type"):
        reg.gauge("train/steps")


def test_registry_jsonl_sink(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("x").set(1.5)
    path = str(tmp_path / "m.jsonl")
    reg.dump_jsonl(path, step=1)
    reg.gauge("x").set(2.5)
    reg.dump_jsonl(path, step=2, arch="a")
    rows = [json.loads(l) for l in open(path)]
    assert [r["step"] for r in rows] == [1, 2]
    assert rows[1]["arch"] == "a"
    assert rows[1]["metrics"]["x"]["value"] == 2.5


def test_record_peak_is_the_one_audited_path():
    reg = MetricsRegistry()
    line = reg.record_peak("train", 2.0 * 2**30, 4.0 * 2**30,
                           budget_bytes=32 * 2**30, note="remat=full")
    assert line == ("train: modeled peak 2.00 GiB vs measured 4.00 GiB "
                    "(modeled/measured 0.50, budget 32 GiB, remat=full)")
    assert reg.gauge("train/modeled_peak_bytes").value == 2.0 * 2**30
    assert reg.gauge("train/measured_peak_bytes").value == 4.0 * 2**30
    assert reg.gauge("train/modeled_over_measured").value == 0.5


# ---------------------------------------------------------------------------
# drift monitor
# ---------------------------------------------------------------------------
def test_drift_monitor_residuals_and_worst():
    reg = MetricsRegistry()
    d = DriftMonitor(reg)
    assert d.record("step_time", 1.0, 1.1) == pytest.approx(0.1)
    d.record("step_time", 1.0, 0.9, step=2)
    d.record("peak_memory", 10.0, 30.0)
    assert d.residuals("step_time") == pytest.approx([0.1, -0.1])
    s = d.summary()
    assert s["step_time"]["mean_abs_rel"] == pytest.approx(0.1)
    assert s["peak_memory"]["mean_abs_rel"] == pytest.approx(2.0)
    assert d.worst() == "peak_memory"
    rep = d.report()
    assert "live-range memory simulator (core/memory)" in rep
    # every record mirrors into the registry
    assert reg.gauge("drift/peak_memory/rel_residual").value == \
        pytest.approx(2.0)


def test_drift_monitor_empty():
    d = DriftMonitor()
    assert d.worst() is None
    assert d.report() == "drift: no observations recorded"


def test_drift_monitor_zero_modeled_sentinel():
    """Regression: a record with modeled == 0 used to produce an inf
    residual that poisoned mean_abs_rel/worst() forever.  It must come
    back as the NaN sentinel and be EXCLUDED from every aggregate."""
    reg = MetricsRegistry()
    d = DriftMonitor(reg)
    rel = d.record("step_time", 0.0, 1.0)
    assert math.isnan(rel)
    d.record("step_time", 1.0, 1.2)
    d.record("bubble", 0.0, 0.5)          # channel with ONLY sentinels
    s = d.summary()
    assert s["step_time"]["n"] == 2       # sentinel rows still counted
    assert s["step_time"]["mean_abs_rel"] == pytest.approx(0.2)
    assert s["step_time"]["last_rel"] == pytest.approx(0.2)
    assert s["bubble"]["mean_abs_rel"] == 0.0
    assert d.worst() == "step_time"       # finite drift outranks sentinels
    assert math.isfinite(s["step_time"]["mean_abs_rel"])
    # the registry never sees the sentinel residual
    assert "drift/bubble/rel_residual" not in reg
    rep = d.report()
    assert "inf" not in rep and "nan" not in rep


def test_modeled_step_time_positive(pp_plan):
    _, model, shape, plan = pp_plan
    step_s = modeled_step_time(model, plan, shape)
    assert step_s is not None and step_s > 0.0
    assert math.isfinite(step_s)


# ---------------------------------------------------------------------------
# trace emitter: schema validity, lane invariants, determinism
# ---------------------------------------------------------------------------
def _full_trace(pp_plan):
    cfg, model, shape, plan = pp_plan
    return plan_trace(model, plan, shape, arch_cfg=cfg)


def test_trace_schema_valid(pp_plan):
    doc = _full_trace(pp_plan).to_doc()
    evs = doc["traceEvents"]
    assert evs and doc["displayTimeUnit"] == "ms"
    for e in evs:
        assert e["ph"] in ("M", "X", "i")
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        assert e["name"]
        if e["ph"] == "X":
            assert e["ts"] >= 0.0 and e["dur"] >= 0.0
    # process/thread metadata present for the modeled pid
    meta = {e["name"] for e in evs if e["ph"] == "M"
            and e["pid"] == PID_MODELED}
    assert {"process_name", "thread_name"} <= meta
    # the pp x cp layout renders compute, comm, ring AND pipeline lanes
    tids = {e["tid"] for e in evs if e["ph"] == "X"
            and e["pid"] == PID_MODELED}
    assert {TID_COMPUTE, TID_COMM} <= tids
    assert any(t >= TID_PIPE_BASE for t in tids)


def test_trace_no_overlap_within_lane(pp_plan):
    doc = _full_trace(pp_plan).to_doc()
    pids_tids = {(e["pid"], e["tid"]) for e in doc["traceEvents"]
                 if e["ph"] == "X"}
    for pid, tid in pids_tids:
        spans = lane_spans(doc, pid, tid)
        for (t0, d0), (t1, _) in zip(spans, spans[1:]):
            assert t0 + d0 <= t1 + 1e-9, \
                f"overlap in lane ({pid},{tid}) at ts={t1}"


def test_trace_deterministic(pp_plan):
    assert _full_trace(pp_plan).to_json() == _full_trace(pp_plan).to_json()


def test_trace_comm_lane_matches_exposed(pp_plan):
    """THE acceptance invariant: non-overlapped comm span time in the
    emitted JSON equals the modeled exposed_s within 1%."""
    from repro.core.autowrap import exposed_comm_time

    cfg, model, shape, plan = pp_plan
    dcfg = plan.dcfg
    metas = model.metas(dcfg)
    b_local = max(1, shape.global_batch // max(1, dcfg.batch_dp))
    stats = model.block_stats(
        dcfg, (b_local, shape.seq_len // max(1, dcfg.cp_size)))
    segs = model.block_segments(dcfg) \
        if hasattr(model, "block_segments") else None
    exposed = exposed_comm_time(plan.bucket_plans["blocks"], metas["blocks"],
                                dcfg, stats, segments=segs)["exposed_s"]
    assert exposed > 0.0
    for repeats in (1, 3):
        tb = plan_trace(model, plan, shape, repeats=repeats, arch_cfg=cfg)
        non = nonoverlapped_comm_s(tb.to_doc())
        assert non == pytest.approx(repeats * exposed, rel=0.01)


# ---------------------------------------------------------------------------
# golden pipeline lanes: one per schedule (M=4, S=2, V=2 for interleaved)
# ---------------------------------------------------------------------------
PIPE_GOLDEN = {
    "gpipe": (8, {0: ["F0", "F1", "F2", "F3"],
                  1: ["F0", "F1", "F2", "F3"]}),
    "1f1b": (16, {0: ["F0", "F1", "B0", "F2", "B1", "F3", "B2", "B3"],
                  1: ["F0", "B0", "F1", "B1", "F2", "B2", "F3", "B3"]}),
    "interleaved": (32, {
        0: ["F0.0", "F1.0", "F0.1", "F1.1", "F2.0", "B0.1", "F3.0", "B0.0",
            "B1.1", "F2.1", "B1.0", "B2.1", "F3.1", "B2.0", "B3.1", "B3.0"],
        1: ["F0.0", "F1.0", "F0.1", "B0.1", "F1.1", "B0.0", "B1.1", "F2.0",
            "B1.0", "F2.1", "B2.1", "F3.0", "B2.0", "F3.1", "B3.1",
            "B3.0"]}),
    "zb": (24, {0: ["F0", "F1", "B0", "F2", "B1", "F3", "B2", "W@0", "B3",
                    "W@1", "W@2", "W@0"],
                1: ["F0", "B0", "F1", "B1", "F2", "B2", "F3", "B3", "W@0",
                    "W@1", "W@2", "W@3"]}),
}


@pytest.mark.parametrize("schedule", sorted(PIPE_GOLDEN))
def test_pipeline_lanes_golden(schedule):
    n_span, lanes = PIPE_GOLDEN[schedule]
    tb = TraceBuilder()
    end = pipeline_lanes(tb, 4, 2, schedule,
                         virtual=2 if schedule == "interleaved" else 1,
                         slot_s=1.0)
    doc = tb.to_doc()
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == n_span
    for stage, want in lanes.items():
        got = [n for _, n in sorted(
            (e["ts"], e["name"]) for e in xs
            if e["tid"] == TID_PIPE_BASE + stage)]
        assert got == want, (schedule, stage)
    assert end > 0.0
    # every microbatch's forward appears on every stage
    for stage in (0, 1):
        names = lanes[stage]
        for m in range(4):
            assert any(n.startswith(f"F{m}") for n in names)


# ---------------------------------------------------------------------------
# serving: scheduler event log + registry + router posterior
# ---------------------------------------------------------------------------
def _serve_plan():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    return plan_serve(model, DCFG, arena_bytes=64 << 20, max_batch=4,
                      max_seq=128, page=16)


def _reqs(n=16):
    return synthetic_trace(n, seed=0, mean_interarrival_s=0.002,
                           prompt_lens=(16, 32, 64), gen_lens=(8, 16, 32))


def test_batcher_events_and_registry():
    plan = _serve_plan()
    reg = MetricsRegistry()
    b = run_virtual(plan, _reqs(), registry=reg, trace=True)
    m = b.metrics()
    assert m["requests"] == 16
    kinds = {e[0] for e in b.events}
    assert {"admit", "prefill", "decode", "finish"} <= kinds
    # virtual clock: measured decode == modeled decode, ratio stays 1.0
    assert b.decode_ratio == pytest.approx(1.0)
    assert b.decode_ewma is not None and b.decode_ewma > 0.0
    assert reg.counter("serving/admitted").value == 16
    assert reg.gauge("serving/p50_s").value == pytest.approx(m["p50_s"])
    assert reg.histogram("serving/prefill_chunk_s").count == \
        m["prefill_chunks"]
    # event windows are monotone on each lane
    dec = [e for e in b.events if e[0] == "decode"]
    for (_, ts0, te0, _), (_, ts1, _, _) in zip(dec, dec[1:]):
        assert te0 <= ts1 + 1e-12


def test_batcher_trace_off_by_default():
    plan = _serve_plan()
    b = run_virtual(plan, _reqs())
    assert b.events is None
    with pytest.raises(ValueError, match="enable_trace"):
        serving_lanes(TraceBuilder(), b)


def test_serving_lanes_from_events():
    plan = _serve_plan()
    b = run_virtual(plan, _reqs(), trace=True)
    tb = TraceBuilder()
    end = serving_lanes(tb, b)
    doc = tb.to_doc()
    assert end > 0.0
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == sum(1 for e in b.events
                          if e[0] in ("prefill", "decode"))


def test_router_posterior_feedback():
    plan = _serve_plan()
    reqs = _reqs()
    # prior: identical replicas, scale 1.0, same as the pure-model router
    r0 = Router([plan, plan])
    base = r0.replicas[0].service_time(reqs[0])
    # a replica observed 2x slower than its roofline projects longer
    r1 = Router([plan, plan], registry=MetricsRegistry())
    for _ in range(64):
        r1.observe_decode(0, measured_step_s=2.0 * plan.decode_step_s)
    assert r1.replicas[0].decode_scale == pytest.approx(2.0, rel=0.01)
    slow = r1.replicas[0].service_time(reqs[0])
    assert slow > base
    assert r1.registry.gauge("router/replica0/decode_scale").value == \
        pytest.approx(r1.replicas[0].decode_scale)
    # prefill term unchanged: only the decode term scales
    assert slow - base == pytest.approx(
        (r1.replicas[0].decode_scale - 1.0) * reqs[0].max_new
        * plan.decode_step_time(plan.max_batch,
                                len(reqs[0].prompt) + reqs[0].max_new / 2))


def test_router_feed_from_batcher():
    plan = _serve_plan()
    b = run_virtual(plan, _reqs(), trace=True)
    r = Router([plan])
    scale = r.feed_from_batcher(0, b)
    # virtual clock: ratio EWMA is 1.0, so the posterior equals the prior
    assert scale == pytest.approx(1.0)
    # and with no feedback at all, routing matches the pure-model router
    r_a, r_b = Router([plan, plan]), Router([plan, plan])
    for req in _reqs():
        assert r_a.route(req) == r_b.route(req)


# ---------------------------------------------------------------------------
# measured quant codec rate (dryrun harvest -> irgraph pricing)
# ---------------------------------------------------------------------------
def test_measured_quant_rate_install_restore():
    from repro.core import hw
    from repro.core.meta import ParamMeta

    metas = {"w": ParamMeta("w", (256, 64))}
    nodes = irgraph.build_nodes(metas, DCFG, None)
    base = irgraph.quant_overhead_s(nodes, "fp8")
    assert base > 0.0
    assert irgraph.quant_codec_rate() == hw.HBM_BANDWIDTH / 2.0
    prev = irgraph.set_measured_quant_rate(hw.HBM_BANDWIDTH / 8.0)
    try:
        assert prev is None
        # 4x slower codec -> 4x the modeled overhead
        assert irgraph.quant_overhead_s(nodes, "fp8") == \
            pytest.approx(4.0 * base)
        # bf16 stays free regardless of the installed rate
        assert irgraph.quant_overhead_s(nodes, "bf16") == 0.0
    finally:
        irgraph.set_measured_quant_rate(prev)
    assert irgraph.quant_overhead_s(nodes, "fp8") == pytest.approx(base)


def test_harvest_quant_timing_smoke():
    from repro.launch.dryrun import harvest_quant_timing

    q = harvest_quant_timing([1 << 14, 1 << 16], iters=2)
    assert q is not None
    assert q["rate_bytes_per_s"] > 0.0 and q["codec"] == "fp8"
    assert 1 <= len(q["samples"]) <= 3
    for s in q["samples"]:
        assert s["t_us"] > 0.0 and s["bytes"] == 2 * s["n_elems"]


# ---------------------------------------------------------------------------
# trainer wire accounting
# ---------------------------------------------------------------------------
def test_step_wire_metrics(pp_plan):
    from repro.train.train_step import step_wire_metrics

    _, model, _, plan = pp_plan
    w = step_wire_metrics(model, plan)
    assert w["total_bytes"] > 0.0
    assert w["by_precision"]
    assert sum(w["by_precision"].values()) == pytest.approx(
        w["total_bytes"])
