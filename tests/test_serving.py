"""Serving subsystem tests: paged KV cache parity, scheduler invariants,
prefix caching, KV codecs, and the admission router.

The load-bearing claim is EXACTNESS: paged decode reconstructs the dense
read view bit-for-bit, so `paged == dense` is asserted with
``np.array_equal`` — no tolerances — per supported family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dist import DistConfig
from repro.core.serving import (ContinuousBatcher, PagePool, PrefixCache,
                                Request, Router, dense_to_pages, plan_serve,
                                run_virtual, simulate_trace, static_schedule,
                                synthetic_trace)
from repro.core.serving.scheduler import _pages_through
from repro.kernels.quant import ops as QOPS
from repro.models import runtime as RT
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch
from repro.train import serve as SV

pytestmark = pytest.mark.serving

# The unit tier runs with ONE device (dist_harness owns multi-device
# parity — its `serving` case re-asserts the paged==dense claim at
# tp2 x dp2); under XLA_FLAGS=--xla_force_host_platform_device_count=4
# these meshes widen and the same tests exercise the sharded paths.
# No env mutation here: subprocess-spawning tests inherit os.environ.
_MESH4 = (2, 2) if jax.device_count() >= 4 else (1, 1)
_MESH2 = (1, 2) if jax.device_count() >= 2 else (1, 1)

DCFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=_MESH4,
                  param_dtype=jnp.float32, reduce_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# PagePool
# ---------------------------------------------------------------------------
def test_page_pool_invariants():
    pool = PagePool(8)
    a = pool.alloc(3)
    assert len(a) == 3 and pool.used == 3
    assert pool.alloc(6) is None          # never partial
    assert pool.used == 3
    pool.retain(a[0])
    assert not pool.release(a[0])         # still referenced
    assert pool.release(a[0])             # now freed
    pool.release_all(a[1:])
    assert pool.available == 8
    pool.check()
    with pytest.raises(AssertionError):
        pool.release(a[0])                # double free


def test_pages_through():
    assert _pages_through(0, 4) == 1
    assert _pages_through(3, 4) == 1
    assert _pages_through(4, 4) == 2
    assert _pages_through(15, 16) == 1


# ---------------------------------------------------------------------------
# plan_serve
# ---------------------------------------------------------------------------
def _plan(arch="qwen3_1_7b", **kw):
    _, model = get_arch(arch, smoke=True)
    kw.setdefault("arena_bytes", 64 << 20)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 128)
    kw.setdefault("page", 16)
    return plan_serve(model, DCFG, **kw)


def test_plan_serve_properties():
    plan = _plan()
    assert plan.n_pages >= plan.max_batch
    assert plan.max_pages_per_seq * plan.page >= 128
    assert plan.prefill_chunk >= plan.page
    assert plan.prefill_chunk & (plan.prefill_chunk - 1) == 0  # pow2
    assert plan.decode_step_s > 0 and plan.prefill_tok_s > 0
    assert plan.arena_bytes <= 64 << 20
    # paged streams only live context; dense streams the full window
    assert (plan.modeled_decode_tok_s(4, 32.0, paged=True)
            >= plan.modeled_decode_tok_s(4, 32.0, paged=False))


def test_plan_serve_rejects_recurrent():
    _, model = get_arch("xlstm_1_3b", smoke=True)
    with pytest.raises(ValueError, match="no paged KV"):
        plan_serve(model, DCFG, arena_bytes=1 << 20, max_batch=2,
                   max_seq=64)


def test_plan_serve_rejects_tiny_arena():
    with pytest.raises(ValueError, match="arena budget"):
        _plan(arena_bytes=1024)


# ---------------------------------------------------------------------------
# KV codec (kernels/quant page storage)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["int8", "fp8"])
def test_kv_codec_roundtrip(codec):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 3, 96))
    q, s = QOPS.encode_kv(x, codec)
    assert s.shape == (2, 5, 3, QOPS.kv_chunks(96))
    y = QOPS.decode_kv(q, s, jnp.float32)
    assert y.shape == x.shape
    tol = 0.02 if codec == "int8" else 0.12
    assert float(jnp.max(jnp.abs(x - y))) <= tol * float(jnp.max(jnp.abs(x)))


def test_kv_codec_layer_helpers_match_ops():
    from repro.models import layers as LY
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 2, 64))
    q1, s1 = LY.kv_quantize(x, "int8")
    q2, s2 = QOPS.encode_kv(x, "int8")
    assert np.array_equal(np.asarray(q1), np.asarray(q2))
    assert np.array_equal(np.asarray(s1), np.asarray(s2))
    y = LY.kv_dequantize(q1, s1, jnp.float32)
    assert np.array_equal(np.asarray(y),
                          np.asarray(QOPS.decode_kv(q2, s2, jnp.float32)))


# ---------------------------------------------------------------------------
# Paged decode == dense decode (EXACT)
# ---------------------------------------------------------------------------
def _serve_setup(arch, codec=None, mesh_shape=None, B=4, prompt=12,
                 gen=4, page=4):
    mesh_shape = mesh_shape or _MESH4
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=mesh_shape,
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      kv_cache_codec=codec)
    cfg, model = get_arch(arch, smoke=True)
    T = prompt + gen
    dp = dcfg.dp_total
    max_pages = T // page
    n_pages_local = (B // dp) * max_pages + 2
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    params = SV.serve_params_from_storage(model, storage, dcfg)
    pf, mesh = SV.make_prefill_step(model, dcfg,
                                    ShapeConfig("p", T, B, "prefill"))
    dec, _ = SV.make_decode_step(model, dcfg,
                                 ShapeConfig("d", T, B, "decode"), mesh=mesh)
    pstep, _ = SV.make_paged_step(
        model, dcfg, ShapeConfig("d", T, B, "decode"), page=page,
        n_pages_local=n_pages_local, max_pages=max_pages, mesh=mesh)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 3,
                              cfg.vocab)
    padded = jnp.pad(toks, ((0, 0), (0, gen)), constant_values=3)
    logits, cache = pf(params, {"tokens": padded})
    return (cfg, model, dcfg, params, dec, pstep, logits, cache,
            dict(B=B, prompt=prompt, gen=gen, page=page, T=T,
                 max_pages=max_pages, n_pages_local=n_pages_local, dp=dp))


def _repage_full(cache, sh):
    """dense_to_pages + allocate the generation pages each row needs."""
    arena, table, pools = dense_to_pages(
        cache, np.full((sh["B"],), sh["prompt"]), sh["page"],
        sh["n_pages_local"], sh["max_pages"], dp_shards=sh["dp"])
    tbl = np.array(table)
    filled = -(-sh["prompt"] // sh["page"])
    for b in range(sh["B"]):
        shard = b // (sh["B"] // sh["dp"])
        ids = pools[shard].alloc(sh["max_pages"] - filled)
        for j, pid in enumerate(ids):
            tbl[b, filled + j] = pid
    return arena, jnp.asarray(tbl), pools


@pytest.mark.parametrize("arch,codec", [
    ("qwen3_1_7b", None), ("qwen3_1_7b", "int8"), ("qwen3_1_7b", "fp8"),
    ("gemma2_27b", None), ("qwen2_moe_a2_7b", None),
])
def test_paged_decode_exact_parity(arch, codec):
    (cfg, model, dcfg, params, dec, pstep, logits, cache,
     sh) = _serve_setup(arch, codec=codec)
    cache_d = jax.tree.map(jnp.copy, cache)
    arena, table, _ = _repage_full(cache, sh)
    tok_d = tok_p = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(sh["gen"]):
        pos = jnp.full((sh["B"],), sh["prompt"] + i, jnp.int32)
        ld, cache_d = dec(params, cache_d, tok_d, pos)
        lp, arena = pstep(params, arena, table, tok_p[:, None],
                          pos[:, None])
        assert np.array_equal(np.asarray(ld), np.asarray(lp)), \
            f"{arch}/{codec} diverged at step {i}"
        tok_d = jnp.argmax(ld, -1).astype(jnp.int32)
        tok_p = jnp.argmax(lp, -1).astype(jnp.int32)


def test_paged_decode_ragged_positions():
    """Rows at different depths decode correctly: row b of a ragged paged
    step matches row b of a per-depth lockstep dense decode."""
    (cfg, model, dcfg, params, dec, pstep, logits, cache,
     sh) = _serve_setup("qwen3_1_7b", mesh_shape=_MESH2, B=2, prompt=8,
                        gen=8, page=4)
    B, prompt = sh["B"], sh["prompt"]
    # advance row 0 by two extra greedy steps (dense, lockstep)
    cache_d = jax.tree.map(jnp.copy, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks_by_step = [tok]
    for i in range(2):
        l, cache_d = dec(params, cache_d, tok,
                         jnp.full((B,), prompt + i, jnp.int32))
        tok = jnp.argmax(l, -1).astype(jnp.int32)
        toks_by_step.append(tok)
    # rebuild a ragged paged state: row 0 at prompt+2, row 1 at prompt
    lengths = np.array([prompt + 2, prompt])
    # materialize the ragged dense cache by zeroing row 1 beyond prompt
    def ragged(a_adv, a_base):
        out = np.array(a_base)
        out[:, 0] = np.asarray(a_adv)[:, 0]
        return jnp.asarray(out)
    cache_r = jax.tree.map(ragged, cache_d, cache)
    arena, table, pools = dense_to_pages(
        cache_r, lengths, sh["page"], sh["n_pages_local"], sh["max_pages"],
        dp_shards=1)
    tbl = np.array(table)
    for b in range(B):
        filled = -(-int(lengths[b]) // sh["page"])
        ids = pools[0].alloc(sh["max_pages"] - filled)
        for j, pid in enumerate(ids):
            tbl[b, filled + j] = pid
    table = jnp.asarray(tbl)
    # ragged step: row 0 decodes token from step 2 at pos prompt+2,
    # row 1 decodes its first generated token at pos prompt
    rtok = jnp.stack([toks_by_step[2][0], toks_by_step[0][1]])
    rpos = jnp.asarray(lengths, jnp.int32)
    lp, arena = pstep(params, arena, table, rtok[:, None], rpos[:, None])
    # reference: dense lockstep logits at the matching depths
    l_ref0, _ = dec(params, jax.tree.map(jnp.copy, cache_d),
                    toks_by_step[2], jnp.full((B,), prompt + 2, jnp.int32))
    l_ref1, _ = dec(params, jax.tree.map(jnp.copy, cache),
                    toks_by_step[0], jnp.full((B,), prompt, jnp.int32))
    np.testing.assert_allclose(np.asarray(lp)[0], np.asarray(l_ref0)[0],
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp)[1], np.asarray(l_ref1)[1],
                               rtol=1e-5, atol=1e-5)


def test_chunked_prefill_matches_full_prefill():
    """Paged chunked prefill (C>1 slabs) reproduces the dense prefill
    cache contents and final logits (no codec: chunked attends its own
    freshly-written slab through the paged read view)."""
    (cfg, model, dcfg, params, dec, pstep, logits, cache,
     sh) = _serve_setup("qwen3_1_7b", mesh_shape=_MESH2, B=2, prompt=8,
                        gen=8, page=4)
    B, prompt, page = sh["B"], sh["prompt"], sh["page"]
    # empty arena + tables covering the whole window
    arena, table, pools = dense_to_pages(
        jax.tree.map(lambda a: jnp.zeros_like(a), cache),
        np.zeros((B,), int), page, sh["n_pages_local"], sh["max_pages"],
        dp_shards=1)
    tbl = np.array(table)
    for b in range(B):
        ids = pools[0].alloc(sh["max_pages"])
        tbl[b] = ids
    table = jnp.asarray(tbl)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 3,
                              cfg.vocab)
    chunk = 4
    for s in range(0, prompt, chunk):
        qpos = jnp.arange(s, s + chunk, dtype=jnp.int32)[None, :].repeat(
            B, 0)
        lp, arena = pstep(params, arena, table, toks[:, s:s + chunk], qpos)
    # reference: a prompt-length dense prefill (the fixture's `logits`
    # came from a padded window, i.e. a LATER position — not comparable)
    pf2, _ = SV.make_prefill_step(
        model, dcfg, ShapeConfig("p2", prompt, B, "prefill"))
    logits_ref, _ = pf2(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lp), np.asarray(logits_ref),
                               rtol=2e-5, atol=2e-5)
    # and the next decode step agrees with dense decode (the padded
    # positions >= prompt in the dense cache are masked out / rewritten)
    tok = jnp.argmax(logits_ref, -1).astype(jnp.int32)
    pos = jnp.full((B,), prompt, jnp.int32)
    ld, _ = dec(params, jax.tree.map(jnp.copy, cache), tok, pos)
    lp2, _ = pstep(params, arena, table, tok[:, None], pos[:, None])
    np.testing.assert_allclose(np.asarray(lp2), np.asarray(ld),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------
def _stub_plan(n_pages=16, max_batch=4, page=4, chunk=8, interleave=2):
    from repro.core.serving.scheduler import ServePlan
    return ServePlan(
        arch="stub", family="dense", page=page, n_pages=n_pages,
        max_pages_per_seq=min(8, n_pages), max_batch=max_batch,
        prefill_chunk=chunk, interleave=interleave, codec=None,
        kv_token_bytes=1024, weight_bytes=1 << 20,
        arena_bytes=n_pages * page * 1024, decode_step_s=1e-3,
        prefill_tok_s=1e5, cp_prefill=1)


def _reqs(n, prompt_len=10, max_new=6, spacing=0.0):
    return [Request(rid=i, prompt=tuple(range(3, 3 + prompt_len)),
                    max_new=max_new, arrival=i * spacing)
            for i in range(n)]


def test_batcher_completes_all_requests():
    plan = _stub_plan()
    b = run_virtual(plan, _reqs(10, spacing=1e-3))
    assert len(b.done) == 10
    assert all(len(s.out) == 6 for s in b.done)
    assert b.pool.used == 0
    b.pool.check()
    m = b.metrics()
    assert m["tok_s"] > 0 and m["p99_s"] >= m["p50_s"]
    assert 0 < m["arena_util"] <= 1.0


def test_batcher_arena_budget_invariant_and_preemption():
    """More live demand than pages: peak never exceeds the pool and
    preemption (LIFO) keeps everything finishing."""
    plan = _stub_plan(n_pages=8, max_batch=4)   # 8 pages, 4 slots
    b = run_virtual(plan, _reqs(8, prompt_len=12, max_new=8))
    assert len(b.done) == 8
    assert b.stats["peak_pages"] <= plan.n_pages
    assert b.stats["preemptions"] > 0
    assert b.pool.used == 0


def test_batcher_interleaves_prefill_with_decode():
    plan = _stub_plan(interleave=2, chunk=4)
    b = ContinuousBatcher(plan)
    for r in _reqs(4, prompt_len=12, max_new=4):
        b.submit(r)
    kinds = []
    while not b.finished():
        act = b.next_action()
        if act is None:
            continue
        kinds.append(act[0])
        if act[0] == "prefill":
            b.on_prefill(act[1], len(act[3]))
        else:
            b.on_decode(act[2] if False else act[1], [7] * len(act[1]))
    # once decode is live, prefill chunks appear between decode runs
    joined = "".join("p" if k == "prefill" else "d" for k in kinds)
    assert "dp" in joined and "pd" in joined, joined


def test_prefix_cache_sharing_and_refcounts():
    pool = PagePool(16)
    pc = PrefixCache()
    page = 4
    prompt = tuple(range(3, 3 + 12))            # 3 full pages
    table = pool.alloc(3)
    pc.insert(prompt, table, pool, page)
    pool.release_all(table)                      # seq done; cache holds refs
    assert pool.used == 3
    hit = pc.lookup(prompt, pool, page)
    assert hit == table                          # same physical pages
    pool.release_all(hit)
    assert pool.used == 3                        # cache still holds them
    freed = pc.reclaim(pool, 3)
    assert freed == 3 and pool.used == 0
    pool.check()


def test_batcher_prefix_hits_skip_prefill_work():
    plan = _stub_plan(n_pages=32, chunk=4)
    prompt = tuple(range(3, 3 + 16))
    reqs = [Request(rid=i, prompt=prompt, max_new=4, arrival=i * 1.0)
            for i in range(4)]
    pc = PrefixCache()
    b = run_virtual(plan, reqs, prefix_cache=pc)
    assert len(b.done) == 4
    m = b.metrics()
    assert m["prefix_hit_rate"] > 0.4            # later requests fast-forward
    # shared fast-forward stops before the last prompt token
    nochain = run_virtual(plan, reqs)
    assert m["prefill_chunks"] < nochain.metrics()["prefill_chunks"]
    assert b.pool.used == len(pc)                # only cache refs remain


def test_continuous_beats_static_on_virtual_clock():
    plan = _plan(max_batch=4, max_seq=128)
    trace = synthetic_trace(24, seed=3, mean_interarrival_s=0.002,
                            prompt_lens=(32, 64), gen_lens=(16, 32))
    cont = run_virtual(plan, trace).metrics()
    stat = static_schedule(plan, trace)
    assert cont["gen_tokens"] == stat["gen_tokens"]
    assert cont["tok_s"] >= stat["tok_s"]
    assert cont["p99_s"] <= stat["p99_s"]


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------
def test_router_balances_and_is_deterministic():
    # smoke-model roofline service is ~20µs/request: drive arrivals well
    # under that so a real backlog forms and spills to the second replica
    plan = _plan(max_batch=4)
    trace = synthetic_trace(40, seed=1, mean_interarrival_s=2e-6,
                            gen_lens=(64, 256))
    r1 = simulate_trace([plan, plan], trace)
    r2 = simulate_trace([plan, plan], trace)
    assert r1 == r2                              # fully deterministic
    assert r1["admitted"] == 40 and r1["rejected"] == 0
    loads = [p["assigned"] for p in r1["per_replica"]]
    assert min(loads) > 0                        # both replicas used


def test_router_more_replicas_no_worse_p99():
    plan = _plan(max_batch=2)
    trace = synthetic_trace(40, seed=2, mean_interarrival_s=0.0005,
                            gen_lens=(64, 128))
    one = simulate_trace([plan], trace)
    four = simulate_trace([plan] * 4, trace)
    assert four["p99_s"] <= one["p99_s"]
    assert four["tok_s"] >= one["tok_s"]


def test_router_admission_control_sheds_load():
    plan = _plan(max_batch=2)
    trace = synthetic_trace(60, seed=4, mean_interarrival_s=1e-5,
                            gen_lens=(256,))
    open_ = simulate_trace([plan], trace)
    gated = simulate_trace([plan], trace, admit_slo_s=open_["p50_s"] / 4)
    assert gated["rejected"] > 0
    assert gated["admitted"] + gated["rejected"] == 60
    assert gated["p99_s"] <= open_["p99_s"]
