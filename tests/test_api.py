"""Device-free unit tests of the `parallelize()` redesign (core/api.py).

Covers: `plan_parallel` resolution and its invariants per registered arch
(stage partitions cover every top-level param group exactly once, equal
layer slices), the stage/unstage storage round-trip (models/staging.py),
the model-contract `stacked_keys` fix, and the BENCH_pipeline.json schema
(satellite CI artifact, mirroring the BENCH_overlap smoke).

Multi-device semantics (pp>1 vs pp=1 exact parity, per-arch Trainer smoke)
live in tests/dist_harness.py cases `trainer_pipeline` /
`trainer_smoke_a/b`.
"""

import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import ParallelPlan, parallelize, plan_parallel
from repro.core.dist import DistConfig
from repro.models import runtime as RT
from repro.models.common import ShapeConfig, StageSpec
from repro.models.registry import (ARCH_IDS, get_arch, get_arch_for_pp)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SHAPE = ShapeConfig("t", 32, 8, "train")


def _pp_cfg(stages: int = 2, **kw) -> DistConfig:
    return DistConfig(mesh_axes=("pipe", "data", "model"),
                      mesh_shape=(stages, 2, 2), pp_axis="pipe",
                      param_dtype=jnp.float32, storage_dtype=jnp.float32,
                      **kw)


def _flat_cfg(**kw) -> DistConfig:
    return DistConfig(mesh_axes=("data", "model"), mesh_shape=(2, 2),
                      param_dtype=jnp.float32, storage_dtype=jnp.float32,
                      **kw)


# ---------------------------------------------------------------------------
# plan_parallel resolution invariants, every registered arch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_plan_parallel_stage_partition_invariants(arch):
    """For every arch: the resolved plan's stage partition covers each
    top-level param group exactly once, slices the stack evenly, and the
    bucket plans cover every stacked group."""
    cfg, model = get_arch_for_pp(arch, n_stages=2)
    dcfg = _pp_cfg(2)
    plan = plan_parallel(model, dcfg, SHAPE)

    assert plan.pipelined and isinstance(plan.stage, StageSpec)
    spec = plan.stage
    metas = model.metas(dcfg)
    declared = [spec.pipelined, *spec.pre_keys, *spec.post_keys,
                *spec.replicated_keys]
    # exactly once: no dupes, no gaps, nothing unknown
    assert len(set(declared)) == len(declared)
    assert set(declared) == set(metas.keys())
    # contiguous slices of the existing stacked dim: equal, or declared
    # uneven (zero-padded slots of layers_per_stage rows each)
    sk = plan.stacked_keys
    assert spec.pipelined in sk
    if spec.stage_layers is not None:
        assert sum(spec.stage_layers) == sk[spec.pipelined]
        assert spec.layers_per_stage >= max(spec.stage_layers)
    else:
        assert spec.layers_per_stage * spec.n_stages == sk[spec.pipelined]
    # owner() resolves every group to a well-defined location
    for k in metas:
        assert spec.owner(k) in (0, spec.n_stages - 1, "all", "sliced")
    # microbatches resolved (default = stage count)
    assert plan.microbatches == 2
    # one bucket plan per stacked group
    assert set(plan.bucket_plans) == set(sk)
    assert "pp=2" in plan.describe()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_plan_parallel_without_pipe_axis(arch):
    cfg, model = get_arch(arch, smoke=True)
    plan = plan_parallel(model, _flat_cfg(), SHAPE)
    assert not plan.pipelined and plan.stage is None
    assert plan.microbatches == 0
    assert set(plan.bucket_plans) == set(plan.stacked_keys)


def test_plan_parallel_rejects_bad_partitions():
    # zamba2's stock smoke config now plans at pp=2 (uneven superblock
    # stages, zero-padded slots) but still rejects a degree with fewer
    # superblocks than stages
    _, model = get_arch("zamba2_1_2b", smoke=True)
    plan = plan_parallel(model, _pp_cfg(2))
    assert plan.stage.stage_layers == (3, 5)
    assert plan.stage.layers_per_stage == 6
    with pytest.raises(ValueError, match="superblock"):
        plan_parallel(model, _pp_cfg(4))
    # a stack that does not split evenly
    _, model = get_arch("qwen3_1_7b", smoke=True)   # n_steps == 2
    with pytest.raises(ValueError, match="equal pipeline stages"):
        plan_parallel(model, _pp_cfg(4))


def test_stage_spec_validate_is_strict():
    _, model = get_arch("deepseek_coder_33b", smoke=True)
    spec = model.stage_spec(2)
    metas = model.metas(_pp_cfg(2))
    # dropping a key -> gap detected
    import dataclasses
    bad = dataclasses.replace(spec, post_keys=("final_norm",))
    with pytest.raises(ValueError, match="missing"):
        bad.validate(metas.keys(), dict(model.stacked_keys))
    # assigning a key twice -> dupe detected
    bad = dataclasses.replace(spec, replicated_keys=("embed",),
                              pre_keys=("embed",))
    with pytest.raises(ValueError, match="twice"):
        bad.validate(metas.keys(), dict(model.stacked_keys))


def test_stacked_keys_is_part_of_the_model_contract():
    """The old `{"blocks": model.n_steps}` fallback raised AttributeError
    for models without n_steps; now every model declares stacked_keys and
    strangers get a pointed TypeError."""
    for arch in ARCH_IDS:
        _, model = get_arch(arch, smoke=True)
        sk = RT.stacked_keys(model)
        assert sk and all(isinstance(v, int) and v >= 1
                          for v in sk.values())

    class NotAModel:
        pass

    with pytest.raises(TypeError, match="stacked_keys"):
        RT.stacked_keys(NotAModel())


def test_tree_to_storage_is_the_api_transform():
    """Satellite: the duplicate full->storage transforms are collapsed."""
    from repro.core.api import shard_params, unshard_params

    assert RT.tree_to_storage is shard_params
    assert RT.tree_from_storage is unshard_params


# ---------------------------------------------------------------------------
# Staging round-trip (models/staging.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen3_1_7b", "seamless_m4t_large_v2",
                                  "zamba2_1_2b"])
def test_stage_unstage_roundtrip(arch):
    """stage_tree/unstage_tree are exact inverses on the owned data (the
    topology-independent checkpoint property), incl. the two-stack enc-dec
    and the replicated shared block."""
    from repro.models import staging

    cfg, model = get_arch_for_pp(arch, n_stages=2)
    dcfg = _pp_cfg(2)
    spec = model.stage_spec(2)
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)

    sharded = staging.pipe_sharded_groups(model, dcfg, spec)
    staged = staging.stage_tree(storage, spec, dcfg, sharded)
    back = staging.unstage_tree(staged, spec, dcfg, sharded)
    flat_a = jax.tree_util.tree_flatten_with_path(storage)[0]
    flat_b = dict((jax.tree_util.keystr(p), v) for p, v in
                  jax.tree_util.tree_flatten_with_path(back)[0])
    for p, v in flat_a:
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(flat_b[jax.tree_util
                                                        .keystr(p)]))

    # staged leaves carry the (S, ...) stage dim; the pipelined stack's
    # slices are real data in every slot
    for k, sub in staged.items():
        for leaf in jax.tree.leaves(sub):
            assert leaf.shape[0] == 2
    # replicated keys: identical slots
    for k in spec.replicated_keys:
        for leaf in jax.tree.leaves(staged[k]):
            np.testing.assert_array_equal(np.asarray(leaf[0]),
                                          np.asarray(leaf[1]))
    # specs and abstract storage agree with the actual staged shapes
    ab = staging.stage_abstract_storage(model, dcfg, spec)
    flat_ab = dict((jax.tree_util.keystr(p), v) for p, v in
                   jax.tree_util.tree_flatten_with_path(ab)[0])
    for p, v in jax.tree_util.tree_flatten_with_path(staged)[0]:
        sd = flat_ab[jax.tree_util.keystr(p)]
        assert tuple(v.shape) == tuple(sd.shape), jax.tree_util.keystr(p)
    specs = staging.stage_storage_specs(model, dcfg)
    for p, s in jax.tree_util.tree_flatten_with_path(specs)[0]:
        assert s[0] == "pipe", jax.tree_util.keystr(p)


def test_parallelize_bundle_flat_mesh_matches_runtime():
    """At pp=1 the bundle is the familiar whole-model path: identical specs
    and a loss step that agrees with the runtime-assembled one."""
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                      param_dtype=jnp.float32, storage_dtype=jnp.float32)
    shape = ShapeConfig("t", 16, 2, "train")
    par = parallelize(model, dcfg, shape)
    assert par.storage_specs == RT.model_storage_specs(model, dcfg)
    storage = par.init_storage(jax.random.PRNGKey(0))
    assert par.stage_storage(storage) is storage      # no-op at pp=1

    from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch
    ds = SyntheticC4(DataConfig(vocab=cfg.vocab, seq_len=16,
                                global_batch=2))
    batch = adapt_batch(ds.batch(0), model.input_specs(shape, dcfg), 0)
    loss, grads = par.loss_step()(storage, batch)

    from jax.sharding import PartitionSpec as P
    step = RT.make_loss_step(model, dcfg)
    fn, _ = RT.wrap_step(model, dcfg, shape, step,
                         (P(), RT.model_storage_specs(model, dcfg)))
    loss_ref, grads_ref = fn(storage, batch)
    np.testing.assert_allclose(float(loss), float(loss_ref), rtol=1e-6)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(grads)[0],
            jax.tree_util.tree_flatten_with_path(grads_ref)[0]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=0,
                                   err_msg=jax.tree_util.keystr(pa))


def test_plan_mismatched_dcfg_rejected():
    _, model = get_arch("qwen3_1_7b", smoke=True)
    plan = plan_parallel(model, _flat_cfg(), SHAPE)
    with pytest.raises(ValueError, match="different DistConfig"):
        parallelize(model, _flat_cfg(bucket_mode="none"), SHAPE, plan=plan)


# ---------------------------------------------------------------------------
# BENCH_pipeline.json emission (tier-1 smoke; schema regressions fail here)
# ---------------------------------------------------------------------------
def test_bench_pipeline_json_schema(tmp_path):
    import json

    sys.path.insert(0, ROOT)
    try:
        from benchmarks import paper_tables as T
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "BENCH_pipeline.json")
    doc = T.pipeline_table(json_path=path)
    on_disk = json.load(open(path))
    assert on_disk == doc
    assert doc["schema"] == "bench_pipeline_v2"
    assert len(doc["archs"]) >= 2
    for arch, rec in doc["archs"].items():
        assert rec["pp_stages"] > 1
        assert rec["layers_per_stage"] * rec["pp_stages"] \
            == rec["n_scan_steps"]
        assert rec["stats_source"] in ("analytic", "measured")
        assert {"gpipe", "1f1b", "zb", "interleaved"} \
            >= set(rec["schedules"]) >= {"gpipe", "1f1b", "zb"}
        # the auto resolution recorded what it picked for this arch
        assert rec["planned_schedule"] in ("gpipe", "1f1b", "zb",
                                           "interleaved")
        for sched, rows in rec["schedules"].items():
            for row in rows.values():
                assert 0.0 <= row["bubble_frac"] < 1.0
                assert row["modeled_step_s"] > 0
                if sched in ("1f1b", "zb"):
                    # the 1F1B memory claim: live activations bounded by S
                    assert row["peak_live_microbatches"] \
                        <= rec["pp_stages"]
                elif sched == "gpipe":
                    assert row["peak_live_microbatches"] \
                        == row["microbatches"]
                else:                   # interleaved: chunk-granular, > 0
                    assert row["virtual"] >= 2
                    assert row["peak_live_microbatches"] >= 1
                if sched == "zb":
                    assert row["w_queue_depth"] >= 1
            # deeper microbatching shrinks the bubble
            bubbles = [r["bubble_frac"] for r in rows.values()]
            assert bubbles == sorted(bubbles, reverse=True) \
                or len(set(bubbles)) == 1
        # the v2 acceptance claim: at EVERY benched microbatch count the
        # new schedules' modeled bubble strictly improves on 1F1B
        for M, base in rec["schedules"]["1f1b"].items():
            for sched in ("zb", "interleaved"):
                if sched in rec["schedules"]:
                    assert rec["schedules"][sched][M]["bubble_frac"] \
                        < base["bubble_frac"], (arch, sched, M)
