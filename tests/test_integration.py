"""Integration: end-to-end training, checkpoint/restart (bit-exact), failure
recovery, straggler monitor, data determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import DistConfig
from repro.core.meta import named_leaves
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticC4
from repro.ft.failures import InjectedFailures, StragglerMonitor
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

DCFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                  param_dtype=jnp.float32, reduce_dtype=jnp.float32)
SHAPE = ShapeConfig("t", 32, 4, "train")


def _trainer(tmp, total=6, fails=(), **kw):
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    tcfg = TrainerConfig(total_steps=total, ckpt_every=2, log_every=1,
                         warmup=2, ckpt_dir=str(tmp), **kw)
    return Trainer(model, DCFG, SHAPE, AdamWConfig(lr=1e-3), tcfg,
                   failure_source=InjectedFailures(fail_at_steps=fails))


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path / "a", total=8)
    _, _, hist = tr.run()
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0]


def test_checkpoint_restart_bit_exact(tmp_path):
    """Train 6 straight vs train 4 + restart from ckpt at 4 + train 2 —
    identical final parameters (the FT restart path)."""
    tr_a = _trainer(tmp_path / "a", total=6)
    storage_a, _, _ = tr_a.run()

    tr_b = _trainer(tmp_path / "b", total=6, stop_after=4)
    tr_b.run()
    tr_b2 = _trainer(tmp_path / "b", total=6)   # resumes from step 4
    storage_b, _, _ = tr_b2.run()

    for (ka, a), (kb, b) in zip(named_leaves(storage_a),
                                named_leaves(storage_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ka} diverged after restart")


def test_failure_injection_recovers(tmp_path):
    """A failure mid-run triggers restore-from-checkpoint and the job still
    reaches total_steps with the same result as an uninterrupted run."""
    tr_ref = _trainer(tmp_path / "ref", total=6)
    storage_ref, _, _ = tr_ref.run()

    tr = _trainer(tmp_path / "f", total=6, fails=(5,))
    storage, _, _ = tr.run()
    assert tr.restarts == 1
    for (ka, a), (_, b) in zip(named_leaves(storage),
                               named_leaves(storage_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{ka} diverged after failure")


def test_async_checkpoint(tmp_path):
    tr = _trainer(tmp_path / "a", total=4, async_ckpt=True)
    tr.run()
    assert tr.ckpt.latest_step() == 4


def test_checkpoint_elastic_layout_independent(tmp_path):
    """Checkpoints restore onto a different DistConfig (here: different
    fsdp padding via different mesh axes count) with identical logical
    values — the elastic-rescale path."""
    from repro.models import runtime as RT
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg_b = DistConfig(mesh_axes=("pod", "data", "model"),
                        mesh_shape=(1, 1, 1),
                        param_dtype=jnp.float32, reduce_dtype=jnp.float32)
    tr = _trainer(tmp_path / "a", total=2)
    storage_a, opt_a, _ = tr.run()
    ck = Checkpointer(str(tmp_path / "a"))
    storage_b, opt_b, _ = ck.restore(2, model, dcfg_b)
    metas_a = model.metas(DCFG)
    metas_b = model.metas(dcfg_b)
    la = {k: RT.tree_from_storage(storage_a[k], metas_a[k], DCFG)
          for k in storage_a}
    lb = {k: RT.tree_from_storage(storage_b[k], metas_b[k], dcfg_b)
          for k in storage_b}
    for (ka, a), (_, b) in zip(named_leaves(la), named_leaves(lb)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=ka)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=2.0, escalate_after=2)
    for _ in range(8):
        assert mon.observe(0.1) == "ok"
    assert mon.observe(0.5) == "straggler"
    assert mon.observe(0.5) == "escalate"
    assert mon.flags == 2


def test_data_deterministic_and_prefetch():
    ds = SyntheticC4(DataConfig(vocab=1000, seq_len=64, global_batch=4,
                                seed=3))
    b1, b2 = ds.batch(7), ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert (ds.batch(8)["tokens"] != b1["tokens"]).any()
    # targets are next-token shifted
    full = ds.batch(7)
    pf = Prefetcher(ds, start_step=0)
    s0, batch0 = pf.next()
    s1, _ = pf.next()
    pf.close()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(batch0["tokens"], ds.batch(0)["tokens"])


def test_grad_compression_trains(tmp_path):
    """bf16 reduce-scatter w/ fp32 master still converges on the smoke
    model (the distributed-optimization trick toggles cleanly)."""
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DCFG.with_(grad_compression=True)
    tcfg = TrainerConfig(total_steps=6, ckpt_every=100, log_every=1,
                         warmup=2, ckpt_dir=str(tmp_path / "gc"))
    tr = Trainer(model, dcfg, SHAPE, AdamWConfig(lr=1e-3), tcfg)
    _, _, hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"]
