"""Every checked-in benchmarks/results/BENCH_*.json validates against its
declared ``schema`` version.

benchmarks/paper_tables.py re-emits these files; this test keeps the
on-disk artifacts honest between regenerations (a bench that changes its
row shape must bump the schema string AND update the validator here).
Runs in tier-1 (auto-marked ``unit``).
"""

import glob
import json
import os

import pytest

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "results")
BENCH_FILES = sorted(glob.glob(os.path.join(RESULTS_DIR, "BENCH_*.json")))

OVERLAP_MODES = {"none", "block", "greedy", "auto_dp"}
QUANT_MODES = {"bf16", "fp8", "fp8_ef", "auto"}
QUANT_ROW = {"exposed_s", "exposed_comm_s", "quant_overhead_s",
             "total_comm_s", "comm_wire_bytes", "n_buckets", "precisions"}
MEMORY_MODES = {"none", "save_dots", "fsdp_only", "full", "auto"}
PIPELINE_SCHEDULES = {"gpipe", "1f1b", "zb", "interleaved"}


def _check_overlap_v2(doc):
    assert doc["mesh"]
    assert doc["archs"]
    for arch, rec in doc["archs"].items():
        assert rec["n_layers"] > 0 and rec["n_scan_steps"] > 0, arch
        assert OVERLAP_MODES <= set(rec["modes"]), arch
        for mode, row in rec["modes"].items():
            assert row["exposed_s"] >= 0 and row["modeled_step_s"] > 0
            assert row["n_buckets"] >= 1
        cp = rec["comm_precision"]
        assert QUANT_MODES <= set(cp), arch
        for q, row in cp.items():
            assert QUANT_ROW <= set(row), (arch, q)
            assert row["comm_wire_bytes"] > 0
            assert row["quant_overhead_s"] >= 0
            # exposed_s is the planner objective: pure comm + codec time
            assert row["exposed_s"] == pytest.approx(
                row["exposed_comm_s"] + row["quant_overhead_s"], abs=1e-12)
            assert len(row["precisions"]) == row["n_buckets"]
        # headline claims of the quant ablation, re-asserted on disk
        bf16 = cp["bf16"]
        assert bf16["quant_overhead_s"] == 0.0
        assert set(bf16["precisions"]) == {"bf16"}
        for q in ("fp8", "fp8_ef"):
            if q in cp:
                assert cp[q]["comm_wire_bytes"] <= \
                    0.55 * bf16["comm_wire_bytes"], (arch, q)
                if bf16["exposed_comm_s"] > 0:
                    assert cp[q]["exposed_comm_s"] < \
                        bf16["exposed_comm_s"], (arch, q)
        assert cp["auto"]["exposed_s"] <= bf16["exposed_s"] + 1e-12, arch


def _check_pipeline_v2(doc):
    assert doc["archs"]
    for arch, rec in doc["archs"].items():
        assert rec["pp_stages"] >= 2, arch
        assert rec["layers_per_stage"] > 0
        assert PIPELINE_SCHEDULES <= set(rec["schedules"]), arch
        for sched, by_mb in rec["schedules"].items():
            assert by_mb, (arch, sched)
            for mb, row in by_mb.items():
                assert int(mb) >= 1 and row["microbatches"] == int(mb)
                assert 0.0 <= row["bubble_frac"] < 1.0, (arch, sched)
                assert row["modeled_step_s"] > 0
                assert row["slots"] >= row["microbatches"]


def _check_memory_v1(doc):
    assert doc["budget_gb"] > 0
    assert doc["archs"]
    for arch, rec in doc["archs"].items():
        assert MEMORY_MODES <= set(rec["modes"]), arch
        for mode, row in rec["modes"].items():
            assert row["peak_bytes"] > 0 and row["modeled_step_s"] > 0
        modes = rec["modes"]
        # more remat never raises the simulated peak
        assert modes["full"]["peak_bytes"] <= \
            modes["fsdp_only"]["peak_bytes"] <= \
            modes["none"]["peak_bytes"], arch


def _check_context_v1(doc):
    assert doc["seq_len"] > 0 and doc["degrees"]
    for arch, rec in doc["archs"].items():
        assert set(map(str, doc["degrees"])) <= set(rec["modes"]), arch
        prev = None
        for cp in sorted(map(int, rec["modes"])):
            row = rec["modes"][str(cp)]
            assert row["cp"] == cp and row["seq_local"] * cp == \
                doc["seq_len"], arch
            # per-device activation residency shrinks with cp
            if prev is not None:
                assert row["act_bytes"] < prev, (arch, cp)
            prev = row["act_bytes"]


SERVING_POLICIES = {"static", "continuous", "continuous_prefix"}
SERVING_PLAN_KEYS = {"page", "n_pages", "max_pages_per_seq", "max_batch",
                     "prefill_chunk", "interleave", "codec",
                     "kv_token_bytes", "arena_bytes", "decode_step_s",
                     "prefill_tok_s", "cp_prefill"}


def _check_serving_v1(doc):
    assert doc["arena_gib"] > 0 and doc["trace_n"] > 0
    assert doc["archs"]
    for arch, rec in doc["archs"].items():
        plan = rec["plan"]
        assert SERVING_PLAN_KEYS <= set(plan), arch
        assert plan["page"] > 0 and plan["n_pages"] >= plan["max_batch"]
        assert plan["arena_bytes"] == \
            plan["n_pages"] * plan["page"] * plan["kv_token_bytes"], arch
        assert plan["decode_step_s"] > 0 and plan["prefill_tok_s"] > 0
        # the arena's bandwidth claim: at equal batch, paged decode streams
        # only live context, dense streams the full allocated window
        m = rec["modeled"]
        assert m["paged_tok_s"] > m["dense_tok_s"] > 0, arch
        pol = rec["policies"]
        assert SERVING_POLICIES <= set(pol), arch
        for name, row in pol.items():
            assert row["requests"] == doc["trace_n"], (arch, name)
            assert row["gen_tokens"] > 0 and row["tok_s"] > 0
            assert 0.0 < row["p50_s"] <= row["p99_s"], (arch, name)
        st, ct = pol["static"], pol["continuous"]
        # the headline serving claims, re-asserted on the disk artifact:
        # continuous batching with chunked prefill beats the static
        # prefill-blocking baseline on virtual-clock tok/s at lower p99
        assert ct["tok_s"] >= st["tok_s"], arch
        assert ct["p99_s"] <= st["p99_s"], arch
        assert 0.0 < ct["arena_util"] <= 1.0, arch
        assert ct["peak_pages"] <= plan["n_pages"], arch
        # shared-system-prompt trace actually shares pages
        assert pol["continuous_prefix"]["prefix_hit_rate"] > 0.0, arch


OBS_CHANNELS = {"step_time", "peak_memory", "decode_rate"}


def _check_obs_v1(doc):
    import math

    ov = doc["overhead"]
    assert ov["step_us"] > 0 and ov["instrument_us"] >= 0
    # the headline claim: per-step instrumentation costs <=2% of a step
    assert ov["overhead_frac"] <= doc["overhead_budget"] <= 0.02
    assert len(doc["archs"]) >= 3
    for arch, rec in doc["archs"].items():
        drift = rec["drift"]
        assert OBS_CHANNELS <= set(drift), arch
        for ch in OBS_CHANNELS:
            row = drift[ch]
            assert row["n"] > 0, (arch, ch)
            for k in ("modeled_mean", "measured_mean", "mean_abs_rel",
                      "last_rel"):
                assert math.isfinite(row[k]), (arch, ch, k)
            assert row["modeled_mean"] > 0 and row["measured_mean"] > 0
        assert rec["worst"] in drift, arch
        assert rec["report"].startswith("drift report"), arch
    tr = doc["trace"]
    assert tr["n_events"] > 0
    assert tr["exposed_s"] > 0
    # the trace invariant: non-overlapped comm-lane time matches the
    # modeled exposed_s within the acceptance tolerance
    assert tr["rel_err"] <= tr["tol"] <= 0.01


def _check_profile_v1(doc):
    import math

    assert len(doc["archs"]) >= 3
    for arch, rec in doc["archs"].items():
        for k in ("wall_step_s", "modeled_before_s", "modeled_after_s",
                  "resid_before", "resid_after", "closure_factor"):
            assert math.isfinite(rec[k]), (arch, k)
        assert rec["wall_step_s"] > 0, arch
        assert rec["n_spans"] > 0, arch
        # the closed-loop claim: the calibrated, replanned step-time
        # promise lands STRICTLY closer to the measured wall step than
        # the analytic prior did
        assert 0.0 <= rec["resid_after"] < rec["resid_before"], arch
        tr = rec["trace"]
        assert tr["n_events"] > 0, arch
        # the overlay must not disturb the modeled comm lanes: the PR-9
        # invariant (non-overlapped comm time == exposed_s) still holds
        assert tr["rel_err"] <= doc["trace_tol"] <= 0.01, arch


VALIDATORS = {
    "bench_overlap_v2": _check_overlap_v2,
    "bench_pipeline_v2": _check_pipeline_v2,
    "bench_memory_v1": _check_memory_v1,
    "bench_context_v1": _check_context_v1,
    "bench_serving_v1": _check_serving_v1,
    "bench_obs_v1": _check_obs_v1,
    "bench_profile_v1": _check_profile_v1,
}


def test_results_dir_nonempty():
    assert BENCH_FILES, f"no BENCH_*.json under {RESULTS_DIR}"


@pytest.mark.parametrize(
    "path", BENCH_FILES, ids=[os.path.basename(p) for p in BENCH_FILES])
def test_bench_json_matches_declared_schema(path):
    with open(path) as f:
        doc = json.load(f)
    schema = doc.get("schema")
    assert schema in VALIDATORS, \
        f"{os.path.basename(path)}: unknown schema {schema!r} — add a " \
        f"validator to tests/test_bench_schemas.py"
    VALIDATORS[schema](doc)
