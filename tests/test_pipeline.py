"""Single-device pipeline schedule tests (unit tier).

The multi-device pp x dp x tp parity lives in tests/dist_harness.py case
`pipeline`; here the pipe axis is a size-1 mesh axis, so the schedule
algebra (slot tables, occupancy, the 1F1B ring-buffer bound) is validated
analytically and gpipe/1F1B collapse to plain microbatched training whose
losses and gradients must match `jax.grad` exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistConfig, make_mesh
from repro.core.compat import shard_map
from repro.core.dist import single_device_config
from repro.core.pipeline import (gpipe, gpipe_grads, gpipe_schedule,
                                 one_f_one_b, one_f_one_b_schedule,
                                 pipeline_grads, schedule_slots)
from jax.sharding import PartitionSpec as P


def _pipe1_cfg() -> DistConfig:
    return DistConfig(mesh_axes=("pipe",), mesh_shape=(1,), fsdp_axes=(),
                      tp_axis=None, pp_axis="pipe")


def _run_on_pipe1(fn, *args, out_specs):
    cfg = _pipe1_cfg()
    mesh = make_mesh(cfg)
    wrapped = shard_map(fn, mesh=mesh,
                        in_specs=tuple(P() for _ in args),
                        out_specs=out_specs, check_vma=False)
    return jax.jit(wrapped)(*args)


# ---------------------------------------------------------------------------
# GPipe schedule algebra
# ---------------------------------------------------------------------------
def test_gpipe_identity_single_stage():
    """Identity stage_fn with S=1: the output equals the input microbatch
    stack — the schedule is a pure pass-through."""
    xs = jax.random.normal(jax.random.PRNGKey(0), (5, 3, 4))
    outs = _run_on_pipe1(lambda xs: gpipe(lambda x: x, xs, 1, "pipe"),
                         xs, out_specs=P())
    np.testing.assert_array_equal(np.asarray(outs), np.asarray(xs))


@pytest.mark.parametrize("M,S", [(1, 1), (4, 1), (1, 4), (4, 4), (6, 3),
                                 (3, 6)])
def test_gpipe_slot_occupancy_analytic(M, S):
    """The (M, S) slot table spans exactly M + S - 1 slots; stage s is busy
    precisely on slots [s, s + M) working on microbatch t - s."""
    sched = gpipe_schedule(M, S)
    assert sched.shape == (M + S - 1, S)
    assert sched.shape[0] == schedule_slots(M, S, "gpipe")
    for s in range(S):
        col = sched[:, s]
        active = np.nonzero(col >= 0)[0]
        assert len(active) == M                       # every mb exactly once
        np.testing.assert_array_equal(active, np.arange(s, s + M))
        np.testing.assert_array_equal(col[active], active - s)


# ---------------------------------------------------------------------------
# 1F1B schedule algebra: occupancy + the S-bounded memory model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,S", [(1, 1), (4, 1), (4, 4), (8, 4), (6, 3)])
def test_1f1b_schedule_occupancy_and_memory_bound(M, S):
    fwd, bwd = one_f_one_b_schedule(M, S)
    T = schedule_slots(M, S, "1f1b")
    assert fwd.shape == bwd.shape == (T, S)
    for s in range(S):
        # each microbatch's forward and backward run exactly once per stage,
        # never in the same slot (opposite parities)
        assert sorted(fwd[fwd[:, s] >= 0, s]) == list(range(M))
        assert sorted(bwd[bwd[:, s] >= 0, s]) == list(range(M))
        assert not np.any((fwd[:, s] >= 0) & (bwd[:, s] >= 0))
        # in-flight microbatches (forward done, backward pending) stay
        # bounded by min(M, S - s) <= S — the 1F1B memory model, vs
        # GPipe's M live activations
        in_flight = 0
        peak = 0
        for t in range(T):
            if fwd[t, s] >= 0:
                in_flight += 1
            peak = max(peak, in_flight)
            if bwd[t, s] >= 0:
                in_flight -= 1
        assert in_flight == 0
        assert peak <= min(M, S - s)
        # causality: backward of m strictly after its forward
        f_slot = {int(m): t for t in range(T) if (m := fwd[t, s]) >= 0}
        b_slot = {int(m): t for t in range(T) if (m := bwd[t, s]) >= 0}
        assert all(b_slot[m] > f_slot[m] for m in range(M))


# ---------------------------------------------------------------------------
# Differentiability: S=1 pipelines == plain microbatched jax.grad
# ---------------------------------------------------------------------------
def _dense_ref(w, xs):
    ys = jnp.tanh(xs @ w)
    return jnp.mean(ys ** 2)


@pytest.mark.parametrize("schedule", ["gpipe", "1f1b"])
def test_single_stage_grads_match_dense(schedule):
    M, B, D = 3, 2, 4
    w = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.5
    xs = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))
    ref_loss = _dense_ref(w, xs)
    ref_dw, ref_dxs = jax.grad(_dense_ref, argnums=(0, 1))(w, xs)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    def loss_fn(y):
        return jnp.mean(y ** 2) / M

    fn = gpipe_grads if schedule == "gpipe" else one_f_one_b
    loss, dw, dxs = _run_on_pipe1(
        lambda w, xs: fn(stage_fn, w, xs, loss_fn, 1, "pipe"),
        w, xs, out_specs=(P(), P(), P()))
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(ref_dw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(dxs), np.asarray(ref_dxs),
                               rtol=1e-5, atol=1e-7)


def test_pipeline_grads_dispatch_validates():
    cfg = single_device_config()          # no pp_axis configured
    with pytest.raises(ValueError):
        pipeline_grads(lambda p, x: x, {}, jnp.zeros((2, 2)),
                       lambda y: 0.0, cfg)
    with pytest.raises(ValueError):
        schedule_slots(4, 2, "wavefront")       # not a known schedule
    # a declared microbatch count must match the xs stack
    cfg_m = DistConfig(mesh_axes=("pipe",), mesh_shape=(1,), fsdp_axes=(),
                       tp_axis=None, pp_axis="pipe", pp_microbatches=8)
    with pytest.raises(ValueError, match="microbatches"):
        pipeline_grads(lambda p, x: x, {}, jnp.zeros((2, 2)),
                       lambda y: 0.0, cfg_m)


# ---------------------------------------------------------------------------
# PR-6 table schedules: interleaved 1F1B (virtual stages) + zero-bubble
# W-split.  Multi-device parity lives in dist_harness case `pipeline_v2`;
# here the tables themselves are validated analytically.
# ---------------------------------------------------------------------------
from repro.core.pipeline import (bubble_fraction, build_pipe_schedule,
                                 schedule_peak_state, zb_queue_depth,
                                 zero_bubble)


@pytest.mark.parametrize("M,S,V", [(2, 2, 2), (4, 2, 2), (8, 2, 4),
                                   (4, 4, 2), (8, 4, 2)])
def test_interleaved_table_validity(M, S, V):
    """Every (virtual chunk, microbatch) forward and backward appears
    exactly once, on its owning rank j % S, at most one work unit per rank
    per slot, and the ring-buffer registers stay within the declared
    depths."""
    sched = build_pipe_schedule(M, S, "interleaved", V)
    VS = V * S
    seen_f, seen_b = set(), set()
    for t in range(sched.slots):
        for s in range(S):
            assert not (sched.f_mb[t, s] >= 0 and sched.b_mb[t, s] >= 0)
            if sched.f_mb[t, s] >= 0:
                seen_f.add((int(sched.f_chunk[t, s]) * S + s,
                            int(sched.f_mb[t, s])))
            if sched.b_mb[t, s] >= 0:
                seen_b.add((int(sched.b_chunk[t, s]) * S + s,
                            int(sched.b_mb[t, s])))
    want = {(j, m) for j in range(VS) for m in range(M)}
    assert seen_f == want and seen_b == want
    assert sched.f_in.max() < sched.depth_in
    assert sched.b_ct.max() < sched.depth_ct
    # the table's own utilization accounting is consistent
    assert sched.slots == schedule_slots(M, S, "interleaved", V)
    assert sched.work_units == 2 * V * M


@pytest.mark.parametrize("M,S", [(2, 2), (4, 2), (8, 2), (4, 4), (8, 4)])
def test_new_schedules_shrink_the_bubble(M, S):
    """The PR-6 claim, analytically: at every benched (M, S) the modeled
    idle fraction of interleaved (V=2) and zb is STRICTLY below 1F1B's
    (S-1)/(M+S-1), and zb fills the cooldown best."""
    base = bubble_fraction(M, S, "1f1b")
    assert base == pytest.approx((S - 1) / (M + S - 1))
    assert bubble_fraction(M, S, "gpipe") == pytest.approx(base)
    bi = bubble_fraction(M, S, "interleaved", 2)
    bz = bubble_fraction(M, S, "zb")
    assert bi < base and bz < base
    assert bz < bi                        # W-fill beats chunking at V=2
    # more virtual chunks shrink the ramps further (where the greedy
    # builder lands on the ideal Megatron pattern; deep V x deep S tables
    # can fall short of it, but never below 1F1B)
    if S == 2 and M % S == 0:
        assert bubble_fraction(M, S, "interleaved", 4) < bi
    assert bubble_fraction(M, S, "interleaved", 4) < base


@pytest.mark.parametrize("M,S", [(2, 2), (4, 2), (8, 4), (4, 4)])
def test_zb_wqueue_fifo_drain(M, S):
    """The weight-grad halves drain from the W queue in microbatch (FIFO)
    order, each strictly after its Bx, never sharing a slot with F or Bx,
    and the declared queue depth bounds the register indices."""
    sched = build_pipe_schedule(M, S, "zb")
    assert zb_queue_depth(M, S) == sched.depth_w
    for s in range(S):
        b_slot = {int(m): t for t in range(sched.slots)
                  if (m := sched.b_mb[t, s]) >= 0}
        w_slots = [t for t in range(sched.slots)
                   if sched.w_idx[t, s] >= 0]
        assert len(w_slots) == M
        for t in w_slots:                 # one work unit per slot
            assert sched.f_mb[t, s] < 0 and sched.b_mb[t, s] < 0
        drains = []
        for m in range(M):                # match push register to drain
            reg = int(sched.b_push[b_slot[m], s])
            assert 0 <= reg < sched.depth_w
            t = next(t for t in w_slots
                     if t > b_slot[m] and int(sched.w_idx[t, s]) == reg
                     and t not in drains)
            drains.append(t)
        assert drains == sorted(drains)   # FIFO in microbatch order


def test_schedule_peak_state_models():
    """The in-flight memory model the simulator consumes: gpipe holds all
    M, 1f1b/zb are bounded by min(M, S-s), interleaved's V chunk slices
    hold MORE chunk-granular state than plain 1F1B on the interior ranks
    (its known memory cost)."""
    assert schedule_peak_state(8, 4, "gpipe") == [8] * 4
    assert schedule_peak_state(8, 4, "1f1b") == [4, 3, 2, 1]
    assert schedule_peak_state(8, 4, "zb") == [4, 3, 2, 1]
    inter = schedule_peak_state(8, 4, "interleaved", 2)
    assert len(inter) == 4 and all(p >= 1 for p in inter)
    # interior ranks: more resident chunk states than 1F1B's stage bound
    assert inter[1] > 3 and inter[2] > 2
    with pytest.raises(ValueError):
        schedule_peak_state(8, 4, "wavefront")


def test_single_stage_zb_grads_match_dense():
    """S=1 zero-bubble == plain microbatched jax.grad: the W-split and
    queue drain must be a pure reordering of the same accumulation."""
    M, B, D = 3, 2, 4
    w = jax.random.normal(jax.random.PRNGKey(1), (D, D)) * 0.5
    xs = jax.random.normal(jax.random.PRNGKey(2), (M, B, D))
    ref_loss = _dense_ref(w, xs)
    ref_dw, ref_dxs = jax.grad(_dense_ref, argnums=(0, 1))(w, xs)

    loss, dw, dxs = _run_on_pipe1(
        lambda w, xs: zero_bubble(lambda p, x: jnp.tanh(x @ p), w, xs,
                                  lambda y: jnp.mean(y ** 2) / M, 1,
                                  "pipe"),
        w, xs, out_specs=(P(), P(), P()))
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(ref_dw),
                               rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(np.asarray(dxs), np.asarray(ref_dxs),
                               rtol=1e-5, atol=1e-7)


def test_production_dcfg_honours_arch_pp_stages():
    """The per-arch recommended pipeline degree (configs satellite) flows
    into the production mesh with its validity checks."""
    from repro.launch.mesh import production_dcfg_for
    from repro.models.registry import get_arch

    for arch, stages in [("llama3_8b", 4), ("deepseek_coder_33b", 2)]:
        cfg, _ = get_arch(arch)
        assert cfg.pp_stages == stages
        assert cfg.n_layers % stages == 0
        d = production_dcfg_for(cfg)
        assert d.pp_axis == "pipe" and d.pp_size == stages
        assert d.mesh_axes[0] == "pipe"              # pipe outermost
        assert d.mesh_shape == (stages, 16 // stages, 16)
    # a degree that doesn't split the layer stack is rejected
    import dataclasses
    bad = dataclasses.replace(get_arch("llama3_8b")[0], pp_stages=5)
    with pytest.raises(ValueError, match="pp_stages"):
        production_dcfg_for(bad)