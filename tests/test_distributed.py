"""Multi-device semantics, via subprocess (8 fake CPU devices).

jax pins the device count at first init, so the main pytest process (which
must see ONE device for smoke tests) delegates to tests/dist_harness.py.
Each case asserts exact equivalence against dense single-device references —
see that module's docstring for coverage.
"""

import os
import subprocess
import sys

import pytest

from repro.core.compat import HAS_VMA

pytestmark = pytest.mark.distributed

# Cases exercising TP-replicated params consumed by TP-varying compute rely
# on the vma replication-transpose (auto-psum of cotangents over the model
# axis) that only the jax>=0.6 shard_map provides; on older jax they are
# version-gated (ROADMAP "Old-jax vma parity gap"). The pipeline case stays
# active everywhere: its cross-rank flows use explicit collectives only.
needs_vma = pytest.mark.skipif(
    not HAS_VMA,
    reason="needs jax>=0.6 shard_map vma replication-transpose semantics")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(case: str, timeout: int = 540):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + ROOT
    out = subprocess.run(
        [sys.executable, "-m", "tests.dist_harness", case],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, \
        f"{case} failed:\n{out.stdout[-3000:]}\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout


def test_storage_roundtrip_multidev():
    _run("roundtrip")


def test_gather_reconstructs_params():
    _run("gather_values")


@needs_vma
def test_vanilla_stack_matches_dense():
    """scan(remat(gather->compute)) == dense reference, all mesh layouts,
    bucketed and per-param plans."""
    _run("vanilla")


@needs_vma
def test_remat_policies_match_dense():
    _run("remat_modes")


@pytest.mark.slow
@needs_vma
def test_prefetch_stack_all_schedules():
    """The hand-scheduled double-buffered scan (paper's reorder+bucket)
    under every Table-6 flag combination x 3 mesh layouts."""
    _run("prefetch", timeout=560)


@needs_vma
def test_prefetch_bucket_plans():
    _run("prefetch_buckets")


@pytest.mark.slow
@needs_vma
def test_all_architectures_mesh_equivalence():
    """All 10 assigned archs: (2 data x 4 model) == single device, exact
    losses and gradients (TP/SP/EP/grouped-GQA paths)."""
    _run("models", timeout=560)


def test_pipeline_parallel_composability():
    """GPipe AND 1F1B over a (pipe, data, model) mesh with FSDP bucket
    gathers inside each stage — exact loss/gradient match vs the sequential
    dense model across bucket modes (paper SS4)."""
    _run("pipeline")


def test_context_parallel_ring_parity():
    """Context parallelism (core/context.py): cp2 x dp2 training — zigzag
    seq sharding + ring attention on the ctx axis — reproduces the
    cp1 x dp4 baseline exactly (losses, assembled grads, one AdamW step)
    for dense and gemma2 (window+softcap), plus the 4-axis composition
    pp2 x dp2 x cp2.  Explicit collectives only (bucket RS over data x ctx,
    reverse-ring ppermute), so exact on every jax version."""
    _run("context", timeout=560)


def test_quantized_collectives_parity():
    """Quantized collectives (kernels/quant + comm_precision): "bf16" is
    bit-exact vs the default path over two AdamW steps; fp8_ag/fp8/fp8_ef/
    auto stay within documented EF-theory tolerance with the error-feedback
    accumulator present exactly when needs_ef.  dp4 x tp1, explicit
    roundtrip before each collective, so exact on every jax version."""
    _run("quant", timeout=560)


def test_remat_vector_parity_pp2_dp2():
    """Per-segment remat policy vectors (incl. a budget-resolved
    remat='auto:<GB>' plan) == the whole-block policy, exactly, at
    pp2 x dp2 through the unified parallelize() path (core/memory)."""
    _run("remat_vector", timeout=560)


def test_trainer_pipeline_full_lm_parity():
    """The unified parallelize() path: full-LM stage partition at pp=2 vs
    the pp=1 baseline — exact losses, assembled grads, and one AdamW step
    (untied, tied/replicated-embedding, and MoE-aux archs).  tp=1, so exact
    on every jax version (explicit collectives only)."""
    _run("trainer_pipeline", timeout=560)


def test_pipeline_v2_schedules():
    """PR-6 schedules: interleaved (V=2) + zb at pp2 x dp4 == pp=1 exactly
    (losses, grads, AdamW steps) for dense + MoE, plus zamba2's uneven
    zero-padded stage partition over two chained train steps.  tp=1, so
    exact on every jax version (explicit collectives only)."""
    _run("pipeline_v2", timeout=560)


def test_serving_paged_decode_parity():
    """Serving (core/serving): paged KV decode at tp2 x dp2 — pages over
    the data axis, heads over model — is BITWISE equal to the dense-cache
    decode on the same mesh (incl. the int8 page codec), and the whole
    prefill->decode pipeline matches the tp1 x dp1 reference within the
    standard cross-mesh tolerance with identical greedy tokens.  Explicit
    collectives only, so exact on every jax version."""
    _run("serving", timeout=560)


@pytest.mark.slow
def test_trainer_pp_smoke_dense_family():
    """Every registered arch runs a pp2 x dp2 x tp2 Trainer smoke (2 steps
    + a staged checkpoint) through the ONE Trainer — dense half."""
    _run("trainer_smoke_a", timeout=560)


@pytest.mark.slow
def test_trainer_pp_smoke_moe_ssm_multimodal():
    """... and the moe/xlstm/encdec/zamba2/vlm half."""
    _run("trainer_smoke_b", timeout=560)
