"""System-level behaviour: the paper's semantics visible in lowered HLO.

These check the *structural* claims — bucketing reduces collective count,
buckets merge payloads, schedules lower coherently — on a small single-device
lowering (collective counts are read from the pre-optimization stablehlo,
which preserves program structure).
"""

import re

import jax
import jax.numpy as jnp
import pytest
from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import DistConfig, make_mesh
from repro.models import runtime as RT
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch

DCFG = DistConfig(mesh_axes=("data", "model"), mesh_shape=(1, 1),
                  param_dtype=jnp.float32, reduce_dtype=jnp.float32)


def _lower(bucket_mode, reorder):
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DCFG.with_(bucket_mode=bucket_mode, reorder=reorder)
    shape = ShapeConfig("t", 32, 2, "train")
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    batch = {
        "tokens": jnp.zeros((2, 32), jnp.int32),
        "targets": jnp.zeros((2, 32), jnp.int32),
        "valid": jnp.ones((2, 32)),
    }
    step = RT.make_loss_step(model, dcfg)
    specs = RT.model_storage_specs(model, dcfg)
    fn, mesh = RT.wrap_step(model, dcfg, shape, step, (P(), specs))
    return fn.lower(storage, batch).as_text()


def _count(txt, op):
    return len(re.findall(rf"stablehlo\.{op}\b", txt))


def test_bucketing_reduces_collective_count():
    """Per-block bucketing merges per-parameter all-gathers (paper SS3.2.1).
    Needs fsdp>1 so the FSDP gathers actually lower — delegated to the
    multi-device harness."""
    from tests.test_distributed import _run
    _run("hlo_structure")


def test_reorder_path_lowers_with_buckets():
    txt = _lower("block", True)
    assert _count(txt, "all_gather") > 0
    assert _count(txt, "reduce_scatter") > 0


def test_auto_wrap_plan_lowers():
    txt = _lower("auto", True)
    assert _count(txt, "all_gather") > 0


def test_quickstart_example_runs():
    import subprocess
    import sys
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run([sys.executable, "examples/quickstart.py"],
                         capture_output=True, text=True, timeout=540,
                         env=env, cwd=root)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout
