"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

TOL = dict(rtol=2e-2, atol=2e-2)      # bf16 sweeps
TOL32 = dict(rtol=2e-4, atol=2e-5)    # fp32 sweeps


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows,d", [(8, 128), (16, 256), (9, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("unit_offset", [False, True])
def test_rmsnorm_sweep(rows, d, dtype, unit_offset):
    from repro.kernels.rmsnorm import ops, ref
    x = (jax.random.normal(jax.random.PRNGKey(0), (rows, d)) * 2).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,))
    got = ops.rmsnorm_pallas(x, w, 1e-5, unit_offset, True)
    want = ref.rmsnorm(x, w, 1e-5, unit_offset)
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_rmsnorm_grad_matches_ref():
    from repro.kernels.rmsnorm import ops, ref
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128,))
    g1 = jax.grad(lambda x, w: ops.rmsnorm_pallas(
        x, w, 1e-5, False, True).sum(), argnums=(0, 1))(x, w)
    g2 = jax.grad(lambda x, w: ref.rmsnorm(x, w).sum(), argnums=(0, 1))(x, w)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL32)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,hd", [(256, 64), (384, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(S, hd, causal, dtype):
    from repro.kernels.flash_attention import kernel as K, ref
    q = jax.random.normal(jax.random.PRNGKey(0), (2, S, hd)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, S, hd)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, S, hd)).astype(dtype)
    got = K.flash_fwd(q, k, v, causal=causal, interpret=True)
    want = ref.attention(q, k, v, causal=causal)
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


@pytest.mark.parametrize("window,softcap", [(64, None), (None, 30.0),
                                            (128, 50.0)])
def test_flash_variants(window, softcap):
    from repro.kernels.flash_attention import kernel as K, ref
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 64))
    got = K.flash_fwd(q, k, v, causal=True, window=window, softcap=softcap,
                      interpret=True)
    want = ref.attention(q, k, v, causal=True, window=window,
                         softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


def test_flash_gqa_wrapper():
    from repro.kernels.flash_attention import ops
    from repro.models.layers import attention_ref
    B, S, H, Kh, hd = 2, 256, 8, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, Kh, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, Kh, hd))
    got = ops.flash_attention(q, k, v, True, None, None, None, True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL32)


# ---------------------------------------------------------------------------
# Cross entropy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,V", [(16, 1000), (24, 5003), (8, 2048)])
def test_xent_sweep(R, V):
    from repro.kernels.cross_entropy import ops, ref
    logits = jax.random.normal(jax.random.PRNGKey(0), (R, V)) * 2
    targets = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    got = ops.fused_xent(logits, targets, True)
    want, _ = ref.xent(logits, targets)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_xent_grad():
    from repro.kernels.cross_entropy import ops, ref
    R, V = 16, 3000
    logits = jax.random.normal(jax.random.PRNGKey(0), (R, V)) * 2
    targets = jax.random.randint(jax.random.PRNGKey(1), (R,), 0, V)
    g = jax.grad(lambda l: ops.fused_xent(l, targets, True).sum())(logits)
    gw = jax.grad(lambda l: ref.xent(l, targets)[0].sum())(logits)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gw),
                               rtol=2e-3, atol=2e-4)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1024, 5000])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_adamw_sweep(n, dtype):
    from repro.kernels.adamw import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (n,)).astype(dtype)
    g = jax.random.normal(ks[1], (n,)).astype(dtype)
    m = jax.random.normal(ks[2], (n,)).astype(dtype) * 0.1
    v = jnp.abs(jax.random.normal(ks[3], (n,))).astype(dtype) * 0.01
    kw = dict(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, wd=0.1, t=jnp.asarray(3))
    got = ops.adamw_update_pallas(p, g, m, v, interpret=True, **kw)
    want = ref.adamw_update(p, g, m, v, **kw)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL32)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("T,H,P,G,N,chunk", [
    (96, 4, 16, 2, 8, 32), (128, 2, 32, 1, 16, 64), (64, 4, 16, 4, 8, 64),
])
def test_ssd_sweep(T, H, P, G, N, chunk):
    from repro.kernels.ssd import ops, ref
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, T, G, N)) * 0.4
    D = jnp.ones((H,))
    got = ops.ssd(x, dt, A, Bm, Cm, D, chunk, True)
    want, _ = ref.ssd_chunked(x, dt, A, Bm, Cm, D=D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """Chunk size is an implementation detail — results must not change."""
    from repro.kernels.ssd import ref
    B, T, H, P, G, N = 1, 128, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, T, G, N)) * 0.4
    y1, _ = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    y2, _ = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)


def test_ssd_step_matches_chunked():
    """Recurrent decode step == chunked over a length-1 sequence chain."""
    from repro.kernels.ssd import ref
    B, T, H, P, G, N = 1, 8, 2, 4, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.4
    Cm = jax.random.normal(ks[4], (B, T, G, N)) * 0.4
    y_chunk, _ = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=T)
    S = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(T):
        S, y = ref.ssd_step(S, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_chunk),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# mLSTM cell (xlstm)
# ---------------------------------------------------------------------------
def test_mlstm_chunk_invariance_and_step():
    from repro.models.xlstm import mlstm_chunked, mlstm_step
    B, T, H, dk, dv = 1, 32, 2, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, T, H, dk))
    k = jax.random.normal(ks[1], (B, T, H, dk))
    v = jax.random.normal(ks[2], (B, T, H, dv))
    i_pre = jax.random.normal(ks[3], (B, T, H))
    f_pre = jax.random.normal(ks[4], (B, T, H)) + 2.0
    y1, s1 = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=8)
    y2, s2 = mlstm_chunked(q, k, v, i_pre, f_pre, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)
    # recurrent form
    state = None
    ys = []
    from repro.models.xlstm import mlstm_step
    import jax.numpy as jnp2
    state = (jnp2.zeros((B, H, dk, dv)), jnp2.zeros((B, H, dk)),
             jnp2.full((B, H), -1e30))
    for t in range(T):
        state, y = mlstm_step(state, q[:, t], k[:, t], v[:, t],
                              i_pre[:, t], f_pre[:, t])
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y1),
                               rtol=2e-4, atol=2e-5)
