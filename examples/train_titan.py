"""End-to-end driver: train a ~100M-parameter qwen3-style model for a few
hundred steps with the full production stack — SimpleFSDP (bucket+reorder),
mixed precision, microbatching, AdamW + cosine schedule, checkpointing, an
injected node failure with automatic restart, and straggler monitoring.

The TorchTitan-equivalent entry point of the paper's evals, at CPU scale.

Run:  PYTHONPATH=src python examples/train_titan.py [--steps 300]
"""

import argparse
import logging
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp

from repro.core.dist import DistConfig
from repro.ft.failures import InjectedFailures
from repro.models.common import ArchConfig, ShapeConfig
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

logging.basicConfig(level=logging.INFO,
                    format="%(asctime)s %(name)s %(message)s")

# ~100M params: 8L x 512d x 8H, 32k vocab
CFG100M = ArchConfig(
    name="titan-100m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=2048, vocab=32_000, head_dim=64,
    qk_norm=True, tie_embeddings=True, pad_to=4,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_titan")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a node failure at this step")
    args = ap.parse_args()

    import jax
    n_dev = jax.device_count()
    dcfg = DistConfig(
        mesh_axes=("data", "model"), mesh_shape=(max(1, n_dev // 2), 2),
        param_dtype=jnp.bfloat16, reduce_dtype=jnp.float32,
        bucket_mode="block", reorder=True, microbatches=2,
    )
    model = build_model(CFG100M)
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                         log_every=10, warmup=20, ckpt_dir=args.ckpt_dir,
                         async_ckpt=True)
    fails = InjectedFailures(fail_at_steps=(args.fail_at,)) \
        if args.fail_at else None
    trainer = Trainer(model, dcfg, shape, AdamWConfig(lr=3e-4), tcfg,
                      failure_source=fails)
    _, _, hist = trainer.run()
    print(f"\nfinal loss {hist[-1]['loss']:.4f} "
          f"(from {hist[0]['loss']:.4f}); {trainer.restarts} restarts; "
          f"{trainer.straggler.flags} straggler flags")
    print(f"params: {CFG100M.n_params()/1e6:.1f}M")


if __name__ == "__main__":
    main()
