"""Quickstart: the paper's two-line user experience, in JAX.

    model = simple_fsdp(model);  model = torch.compile(model)
becomes
    sharded, metas, fsdp_apply = simple_fsdp(apply_fn, params, dcfg)
    step = jax.jit(shard_map(...))

Wraps a tiny hand-written MLP language model (NOT from the model zoo — the
point is bring-your-own-module), trains a few steps under SimpleFSDP
semantics with per-parameter sharding + bucketed gathers, and prints losses.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import DistConfig, make_mesh, simple_fsdp
from repro.core.meta import named_leaves

VOCAB, D, H, SEQ, BATCH = 512, 64, 128, 32, 16


def apply_fn(params, tokens):
    """An ordinary model function written with NO distribution logic."""
    x = params["embed"][tokens]
    for blk in params["blocks"]:
        h = jnp.tanh(x @ blk["w1"] + blk["b1"])
        x = x + h @ blk["w2"]
    return x @ params["head"]


def init_params(key):
    ks = jax.random.split(key, 8)
    blocks = [
        {"w1": jax.random.normal(ks[i], (D, H)) * 0.05,
         "b1": jnp.zeros((H,)),
         "w2": jax.random.normal(ks[i + 3], (H, D)) * 0.05}
        for i in range(3)
    ]
    return {
        "embed": jax.random.normal(ks[6], (VOCAB, D)) * 0.02,
        "blocks": blocks,
        "head": jax.random.normal(ks[7], (D, VOCAB)) * 0.02,
    }


def main():
    dcfg = DistConfig(mesh_axes=("data", "model"),
                      mesh_shape=(jax.device_count(), 1),
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      bucket_mode="block")
    mesh = make_mesh(dcfg)

    # --- the simple_fsdp() one-liner -------------------------------------
    params = init_params(jax.random.PRNGKey(0))
    sharded, metas, fsdp_apply = simple_fsdp(apply_fn, params, dcfg)

    def step(sharded, tokens, targets):
        def loss_fn(p):
            logits = fsdp_apply(p, tokens)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.take_along_axis(logp, targets[..., None], -1)
            return nll.mean() / dcfg.tp_size
        loss, grads = jax.value_and_grad(loss_fn)(sharded)
        new = jax.tree.map(lambda p, g: p - 0.5 * g, sharded, grads)
        return lax.pmean(loss, ("data",)) * dcfg.tp_size, new

    from repro.core.meta import storage_specs
    pspecs = jax.tree.map(lambda m: m.storage_spec(dcfg), metas,
                          is_leaf=lambda x: hasattr(x, "storage_spec"))
    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P("data"), P("data")),
        out_specs=(P(), pspecs)))

    key = jax.random.PRNGKey(1)
    for i in range(10):
        key, k1 = jax.random.split(key)
        toks = jax.random.randint(k1, (BATCH, SEQ + 1), 0, VOCAB)
        loss, sharded = fn(sharded, toks[:, :-1], toks[:, 1:])
        print(f"step {i} loss {float(loss):.4f}")
    n = sum(v.size for _, v in named_leaves(params))
    print(f"trained {n/1e3:.0f}K params FSDP-sharded over "
          f"{jax.device_count()} devices")


if __name__ == "__main__":
    main()
