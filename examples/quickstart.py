"""Quickstart: the paper's two-line user experience, in JAX.

    model = simple_fsdp(model);  model = torch.compile(model)
becomes ONE entry point:

    par = parallelize(model, dcfg, shape)       # resolves a ParallelPlan
    step = par.train_step(ocfg)                 # jit(shard_map(...))

`parallelize` works for every registered architecture and every mesh —
FSDP x TP, with ``pp_axis`` set the SAME call returns a pipelined
(GPipe/1F1B) step over per-stage SimpleFSDP storage, and with ``cp_axis``
set the sequence shards over a 'ctx' axis with ring attention
(core/context.py): pp x dp x cp x tp is a config flip, not different code.
The 4-axis mesh section below explains the axis ordering.

The original bring-your-own-module wrapper `simple_fsdp(apply_fn, params,
dcfg)` still exists as a DEPRECATED shim (second half of this file) for raw
apply functions with no model contract.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import DistConfig, make_mesh, parallelize, simple_fsdp
from repro.core.compat import shard_map
from jax.sharding import PartitionSpec as P


def main():
    # --- the parallelize() one-liner -------------------------------------
    from repro.data.pipeline import DataConfig, SyntheticC4, adapt_batch
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.optim.adamw import AdamWConfig

    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(4, 2),
                      param_dtype=jnp.float32, storage_dtype=jnp.float32)
    # pipelining is the same call with a pipe axis, e.g.:
    #   dcfg = DistConfig(mesh_axes=("pipe", "data", "model"),
    #                     mesh_shape=(2, 2, 2), pp_axis="pipe")
    shape = ShapeConfig("train", 64, 8, "train")

    par = parallelize(model, dcfg, shape)           # frozen ParallelPlan
    print("plan:", par.plan.describe())

    # --- budgeted auto-SAC (core/memory): two lines pick the cheapest
    # per-segment remat (+offload) whose modeled peak fits the HBM budget
    par_auto = parallelize(model, dcfg.with_(remat="auto:8"), shape)
    print("auto-SAC plan:", par_auto.plan.memory.describe(),
          "->", par_auto.plan.exec_dcfg.remat)

    # --- choosing a comm precision (kernels/quant) -----------------------
    # DistConfig.comm_precision shrinks how many bytes each collective
    # moves (the planners already minimize WHEN comm happens):
    #   "bf16"   default wire dtype — BIT-exact vs the untouched path;
    #   "fp8_ag" quantize the param all-gathers only (deterministic
    #            round-to-nearest, per-128-elem-chunk fp32 scales,
    #            ~0.52x the bytes) — gradients stay full precision;
    #   "fp8"    both directions: AG as above + STOCHASTICALLY-rounded
    #            grad reduce-scatters (unbiased, no state);
    #   "fp8_ef" adds a persistent error-feedback accumulator in the
    #            optimizer state (opt_state["ef"], fp32 per shard): the
    #            residual each quantized step leaves behind is re-added
    #            to the next gradient, so the Markov-et-al. convergence
    #            guarantee applies;
    #   "auto"   per-BUCKET choice: the auto_dp planner searches
    #            partitions x {bf16, fp8_ag, fp8_ef} jointly, paying the
    #            modeled quantize/dequantize time and keeping bf16
    #            wherever comm is already hidden (ties break to bf16).
    # The wire codec is a Pallas quantize/dequantize kernel pair fused
    # into the flat-buffer pack/unpack path (kernels/quant/); run
    # `python -m benchmarks.run fig4` for the per-arch exposed-comm
    # ablation, or pytest -m quant for the parity suite.
    par_q = parallelize(model, dcfg.with_(comm_precision="auto"), shape)
    print("quant plan:", par_q.plan.describe())

    # --- picking a pipeline schedule (core/pipeline.py) ------------------
    # Four pp_schedule values: "gpipe", "1f1b", "interleaved", "zb" — and
    # "auto" (the production_dcfg default), which scores all of them by
    # modeled bubble fraction, tie-broken by in-flight activation memory,
    # and stamps the argmin into the plan (plan.pp_schedule/pp_virtual).
    # Rules of thumb behind what auto picks:
    #   * "zb" (zero-bubble W-split) beats plain 1F1B at every M: the
    #     weight-grad halves drain into the cooldown ramp at NO extra
    #     activation cost (same min(M, S-s) bound; the W queue holds
    #     parameter-GRADIENT slices instead).
    #   * "interleaved" (V virtual stage chunks per rank) shrinks the
    #     warmup/cooldown ramps ~1/V and wins on bubble when the stage
    #     slice chunks evenly (layers_per_stage % V == 0, chunkable
    #     partition) and M is small — but each rank then HOLDS ~V x the
    #     in-flight chunk states.  Under a tight remat="auto:<GB>" budget
    #     that extra in-flight memory can force a costlier remat vector
    #     than the bubble win is worth — the memory simulator models the
    #     schedule (in_flight_microbatches), so compare plan.memory.peak
    #     across explicit pp_schedule choices before overriding auto.
    #   * "gpipe" only ever matches 1f1b's bubble and holds all M
    #     microbatches — it survives as the forward-only eval path.
    # e.g.: dcfg_pp = DistConfig(mesh_axes=("pipe", "data", "model"),
    #                            mesh_shape=(2, 2, 2), pp_axis="pipe",
    #                            pp_schedule="auto")  # or "zb", or
    #                            # "interleaved" with pp_virtual=V

    # --- context parallelism (core/context.py): the 4-axis mesh ----------
    # (pipe, data, ctx, model) — each axis carries a different traffic
    # class, ordered by how much interconnect it needs:
    #   pipe  OUTERMOST: one tiny point-to-point activation send per slot
    #         (tolerates the slowest links, even DCN);
    #   data  fat FSDP all-gathers / reduce-scatters (bulk ICI bandwidth);
    #   ctx   ring-attention ppermute — one KV block per layer per hop,
    #         lighter than FSDP gathers, heavier than pipe sends, which is
    #         why ctx sits BETWEEN data and model;
    #   model INNERMOST: the highest-frequency TP psums.
    # The ctx axis shards the SEQUENCE: rows are zigzag-chunked so every
    # rank owns equal causal work, attention runs as a ring with the next
    # KV exchange overlapped behind the current chunk's compute, and the
    # ctx axis joins fsdp_axes so params shard over data x ctx (all
    # cross-ctx gradients ride explicit collectives).  Feed the step
    # zigzag-permuted batches (the Trainer does this automatically).
    from repro.core.context import zigzag_batch
    dcfg_cp = DistConfig(mesh_axes=("data", "ctx", "model"),
                         mesh_shape=(2, 2, 2), fsdp_axes=("data", "ctx"),
                         cp_axis="ctx",
                         param_dtype=jnp.float32, storage_dtype=jnp.float32)
    par_cp = parallelize(model, dcfg_cp, shape)
    print("cp plan:", par_cp.plan.describe())      # ... cp=2(ring) ...
    st_cp = par_cp.init_storage(jax.random.PRNGKey(0))
    from repro.data.pipeline import DataConfig as _DC, SyntheticC4 as _SC
    from repro.data.pipeline import adapt_batch as _ab
    b0 = _ab(_SC(_DC(vocab=cfg.vocab, seq_len=shape.seq_len,
                     global_batch=shape.global_batch)).batch(0),
             model.input_specs(shape, dcfg_cp), 0)
    loss = par_cp.loss_step(with_grads=False)(st_cp,
                                              zigzag_batch(b0, dcfg_cp))
    print(f"cp=2 ring-attention loss {float(loss):.4f} "
          f"(seq/device = {shape.seq_len // dcfg_cp.cp_size})")

    step = par.train_step(AdamWConfig(lr=1e-3))
    storage = par.init_storage(jax.random.PRNGKey(0))

    from repro.optim.adamw import init_opt_state
    opt = init_opt_state(storage)
    data = SyntheticC4(DataConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                                  global_batch=shape.global_batch))
    specs = model.input_specs(shape, dcfg)
    for i in range(5):
        batch = adapt_batch(data.batch(i), specs, step=i)
        storage, opt, metrics = step(storage, opt, batch)
        print(f"step {i} loss {float(metrics['loss']):.4f}")

    # --- serving (core/serving): plan -> prefill -> continuous decode ----
    serving_quickstart()

    # --- observability (core/obs): trace + registry + drift --------------
    observability_quickstart()

    # --- DEPRECATED: bring-your-own-module simple_fsdp shim --------------
    byo_quickstart()


def serving_quickstart():
    """Inference mirrors training: ONE frozen plan, executed by dumb loops.

    `plan_serve` is the serving analogue of `parallelize` — it freezes a
    ServePlan (page size, pool capacity, decode slots, chunked-prefill
    chunk, modeled service rates) from the hw.py roofline and the KV-arena
    byte budget.  The paged KV cache stores every sequence as fixed-size
    pages in a pooled arena (heads sharded over 'model', pages over the
    data axes) and decodes through a gather that reconstructs the dense
    logical view — so paged decode is BITWISE equal to the dense cache
    path (tests/test_serving.py asserts exact parity per family).

    When to turn the knobs:
      * prefix caching (PrefixCache): workloads with a shared system
        prompt — full prompt pages are refcounted and re-used across
        requests, so repeated prefixes prefill once;
      * int8/fp8 pages (DistConfig.kv_cache_codec="int8"/"fp8"): halves
        (or quarters) arena bytes per token via the kernels/quant codec
        (per-128-chunk scales) — more live sequences per budget, at a
        small dequant error priced by `pytest -m serving` tolerances.
    """
    import numpy as np

    from repro.core.serving import (PrefixCache, Request, dense_to_pages,
                                    plan_serve, run_virtual, synthetic_trace)
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch
    from repro.models import runtime as RT
    from repro.train import serve as SV

    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(2, 2),
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32)
    plan = plan_serve(model, dcfg, arena_bytes=64 * 2**20, max_batch=4,
                      max_seq=128, page=16)
    print(f"serve plan: page={plan.page} pool={plan.n_pages}p "
          f"slots={plan.max_batch} chunk={plan.prefill_chunk} "
          f"decode={plan.decode_step_s*1e6:.2f}us")

    # prefill once (dense), scatter into the paged arena, decode paged:
    B, prompt, gen, page = 4, 24, 8, 8
    T = prompt + gen
    max_pages, n_pages_local = T // page, (B // 2) * (T // page) + 2
    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    params = SV.serve_params_from_storage(model, storage, dcfg)
    pf, mesh = SV.make_prefill_step(model, dcfg,
                                    ShapeConfig("p", T, B, "prefill"))
    pstep, _ = SV.make_paged_step(
        model, dcfg, ShapeConfig("d", T, B, "decode"), page=page,
        n_pages_local=n_pages_local, max_pages=max_pages, mesh=mesh)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, prompt), 3,
                              cfg.vocab)
    logits, cache = pf(params, {"tokens": jnp.pad(
        toks, ((0, 0), (0, gen)), constant_values=3)})
    arena, table, pools = dense_to_pages(
        cache, np.full((B,), prompt), page, n_pages_local, max_pages,
        dp_shards=dcfg.dp_total)
    tbl = np.array(table)
    filled = -(-prompt // page)
    for b in range(B):
        for j, pid in enumerate(pools[b // (B // 2)].alloc(
                max_pages - filled)):
            tbl[b, filled + j] = pid
    table = jnp.asarray(tbl)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(gen):
        pos = jnp.full((B,), prompt + i, jnp.int32)
        lg, arena = pstep(params, arena, table, tok[:, None], pos[:, None])
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
    print(f"paged decode: {B} seqs x {gen} tokens, last ids "
          f"{np.asarray(tok).tolist()}")

    # continuous batching on the plan's virtual clock (deterministic):
    trace = synthetic_trace(16, seed=0,
                            mean_interarrival_s=plan.decode_step_s,
                            prompt_lens=(24, 48), gen_lens=(8, 16))
    m = run_virtual(plan, trace, prefix_cache=PrefixCache()).metrics()
    print(f"continuous batching: {m['requests']} reqs "
          f"{m['tok_s']:.0f} tok/s p99={m['p99_s']*1e3:.2f}ms "
          f"preempt={m['preemptions']} arena_util={m['arena_util']:.2f}")


def observability_quickstart():
    """Every cost model in the repo renders into ONE timeline and ONE
    registry (core/obs), closing the model -> measure loop:

      * `plan_trace(model, plan, shape)` walks the plan's own executed
        schedules — pooled AG/RS hiding windows, pipeline slot tables,
        ring hops, a traced serving batcher — into Chrome-trace JSON
        (open the saved file at https://ui.perfetto.dev).  The layout is
        exact: comm-lane time not covered by a compute span IS the
        planner's modeled `exposed_s` (tests assert the match within 1%).
      * `MetricsRegistry` is the typed counter/gauge/histogram sink the
        Trainer, batcher, and router all write through; JSONL snapshots
        via `TrainerConfig.metrics_jsonl` / `--metrics-jsonl`.
      * `DriftMonitor` scores measured-vs-modeled residuals per channel
        (step time, peak memory, decode rate) and names the
        worst-drifting cost model — `benchmarks/run.py obs --json` tracks
        it per arch in BENCH_obs.json.
    """
    import tempfile

    from repro.core.api import plan_parallel
    from repro.core.obs import (DriftMonitor, MetricsRegistry,
                                modeled_step_time, nonoverlapped_comm_s,
                                plan_trace)
    from repro.models.common import ShapeConfig
    from repro.models.registry import get_arch

    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"),
                      mesh_shape=(jax.device_count(), 1),
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      bucket_mode="auto")
    shape = ShapeConfig("t", 64, 8, "train")
    plan = plan_parallel(model, dcfg, shape)

    # one Perfetto-openable timeline of everything the plan promises
    tb = plan_trace(model, plan, shape, repeats=2, arch_cfg=cfg)
    path = tempfile.mktemp(suffix=".trace.json")
    tb.save(path)
    print(f"trace: {len(tb.events)} events -> {path} "
          f"(exposed comm {nonoverlapped_comm_s(tb.to_doc())*1e6:.1f}us)")

    # registry + drift: record a 'measured' step against the plan's promise
    reg = MetricsRegistry()
    drift = DriftMonitor(reg)
    promised = modeled_step_time(model, plan, shape)
    drift.record("step_time", promised, promised * 1.25, step=0)
    reg.gauge("train/step_time_s").set(promised * 1.25)
    print(reg.record_peak("quickstart", 2 * 2**30, 3 * 2**30))
    print(drift.report())

    # --- profile -> calibrate -> replan (core/obs/profile + calibrate) ---
    # When the drift monitor says the step-time promise is off, close the
    # loop: `profile_step` MEASURES the executed schedule (per-segment
    # compute sub-steps, per-bucket flat-buffer AG/RS, quant codec rates,
    # the wall step), `replan` re-runs every planner (bucket partition +
    # precision DP, auto:<GB> remat, microbatches, pp_schedule='auto')
    # against the calibrated stats and measured rates.  Attaching the
    # profile to plan_trace adds a PID 2 'measured' track aligned
    # span-for-span under the modeled lanes — each span carries its
    # rel_residual, so the overlay shows WHERE the model is wrong; the
    # modeled lanes themselves are untouched.  The same loop runs inside
    # the Trainer (`replan_threshold=` / --replan-threshold); trust
    # --replan-apply once the logged delta is stable across a few replans
    # — it restarts through a checkpoint, costing one save/restore +
    # recompile.
    from repro.core.obs import calibrated_step_time, profile_step, replan

    prof = profile_step(model, plan, shape, steps=1)
    new_plan, delta = replan(model, plan, shape, prof)
    resid_before = abs(promised - prof.wall_step_s) / prof.wall_step_s
    resid_after = abs(
        calibrated_step_time(model, new_plan, shape, prof)
        - prof.wall_step_s) / prof.wall_step_s
    print(f"profile: wall {prof.wall_step_s*1e3:.1f}ms, "
          f"step-time residual {resid_before:.2f} -> {resid_after:.2e} "
          f"(replan changed={delta['changed']})")
    tb2 = plan_trace(model, plan, shape, arch_cfg=cfg, profile=prof)
    path2 = tempfile.mktemp(suffix=".overlay.trace.json")
    tb2.save(path2)
    print(f"overlay trace: {len(tb2.events)} events -> {path2}")


VOCAB, D, H, SEQ, BATCH = 512, 64, 128, 32, 16


def apply_fn(params, tokens):
    """An ordinary model function written with NO distribution logic."""
    x = params["embed"][tokens]
    for blk in params["blocks"]:
        h = jnp.tanh(x @ blk["w1"] + blk["b1"])
        x = x + h @ blk["w2"]
    return x @ params["head"]


def init_params(key):
    ks = jax.random.split(key, 8)
    blocks = [
        {"w1": jax.random.normal(ks[i], (D, H)) * 0.05,
         "b1": jnp.zeros((H,)),
         "w2": jax.random.normal(ks[i + 3], (H, D)) * 0.05}
        for i in range(3)
    ]
    return {
        "embed": jax.random.normal(ks[6], (VOCAB, D)) * 0.02,
        "blocks": blocks,
        "head": jax.random.normal(ks[7], (D, VOCAB)) * 0.02,
    }


def byo_quickstart():
    """The pre-ParallelPlan API, kept as a deprecation shim: raw apply_fn +
    shaped params in, (sharded, metas, fsdp_apply) out."""
    dcfg = DistConfig(mesh_axes=("data", "model"),
                      mesh_shape=(jax.device_count(), 1),
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      bucket_mode="block")
    mesh = make_mesh(dcfg)

    params = init_params(jax.random.PRNGKey(0))
    sharded, metas, fsdp_apply = simple_fsdp(apply_fn, params, dcfg)

    def step(sharded, tokens, targets):
        def loss_fn(p):
            logits = fsdp_apply(p, tokens)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32))
            nll = -jnp.take_along_axis(logp, targets[..., None], -1)
            return nll.mean() / dcfg.tp_size
        loss, grads = jax.value_and_grad(loss_fn)(sharded)
        new = jax.tree.map(lambda p, g: p - 0.5 * g, sharded, grads)
        return lax.pmean(loss, ("data",)) * dcfg.tp_size, new

    pspecs = jax.tree.map(lambda m: m.storage_spec(dcfg), metas,
                          is_leaf=lambda x: hasattr(x, "storage_spec"))
    fn = jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(pspecs, P("data"), P("data")),
        out_specs=(P(), pspecs)))

    key = jax.random.PRNGKey(1)
    for i in range(5):
        key, k1 = jax.random.split(key)
        toks = jax.random.randint(k1, (BATCH, SEQ + 1), 0, VOCAB)
        loss, sharded = fn(sharded, toks[:, :-1], toks[:, 1:])
        print(f"byo step {i} loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
