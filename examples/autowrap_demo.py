"""Auto-wrapping demo (paper SS3.3.2): run the greedy Algorithm 1 AND the
exposure-minimizing DP over a real architecture's per-parameter comm nodes
and print the chosen buckets plus their modeled exposure, next to the manual
per-block plan.

Run:  PYTHONPATH=src python examples/autowrap_demo.py [--arch deepseek_coder_33b]
"""

import argparse

from repro.core.autowrap import auto_dp_plan, auto_plan, exposed_comm_time
from repro.core.bucketing import per_param_plan, whole_block_plan
from repro.launch.mesh import production_dcfg
from repro.models.registry import get_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek_coder_33b")
    args = ap.parse_args()

    cfg, model = get_arch(args.arch)
    dcfg = production_dcfg()
    metas = model.block_metas(dcfg)
    stats = model.block_stats(dcfg, (1, 4096))  # per-device microbatch

    plans = {
        "per-param (vanilla)": per_param_plan(metas),
        "per-block (manual, paper eval setting)": whole_block_plan(metas),
        "auto (greedy Alg. 1)": auto_plan(metas, dcfg, stats),
        "auto_dp (exposure-minimizing DP)": auto_dp_plan(metas, dcfg, stats),
    }
    print(f"{args.arch} on 16x16 v5e, one transformer block:\n")
    for name, plan in plans.items():
        r = exposed_comm_time(plan, metas, dcfg, stats)
        print(f"{name:42s} buckets={r['n_buckets']:3d} "
              f"exposed={r['exposed_s']*1e6:9.1f}us "
              f"total_comm={r['total_comm_s']*1e6:9.1f}us "
              f"compute={r['compute_s']*1e6:9.1f}us")
    auto = plans["auto_dp (exposure-minimizing DP)"]
    print("\nauto_dp buckets:")
    for i, grp in enumerate(auto.groups):
        print(f"  bucket {i}: {list(grp)}")


if __name__ == "__main__":
    main()
