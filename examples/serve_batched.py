"""Batched serving: prefill a prompt batch, then greedy-decode continuations
with the TP-sharded KV cache (int8-quantized) — the inference side of the
framework (decode_32k / long_500k cells use exactly these steps).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist import DistConfig
from repro.models import runtime as RT
from repro.models.common import ShapeConfig
from repro.models.registry import get_arch
from repro.train import serve as SV


def main():
    cfg, model = get_arch("qwen3_1_7b", smoke=True)
    dcfg = DistConfig(mesh_axes=("data", "model"), mesh_shape=(2, 4),
                      param_dtype=jnp.float32, reduce_dtype=jnp.float32,
                      kv_cache_int8=True)
    B, prompt_len, gen = 4, 24, 8
    T = prompt_len + gen

    storage = RT.init_storage(model, jax.random.PRNGKey(0), dcfg)
    params = SV.serve_params_from_storage(model, storage, dcfg)

    prefill, mesh = SV.make_prefill_step(
        model, dcfg, ShapeConfig("p", T, B, "prefill"))
    decode, _ = SV.make_decode_step(
        model, dcfg, ShapeConfig("d", T, B, "decode"), mesh=mesh)

    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, prompt_len),
                                 3, cfg.vocab)
    # pad prompt to the full cache length for prefill cache allocation
    padded = jnp.pad(prompts, ((0, 0), (0, gen)), constant_values=3)
    logits, cache = prefill(params, {"tokens": padded})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    outs = [tok]
    for i in range(gen - 1):
        # per-request positions (B,): rows may sit at different depths
        # under continuous batching; here the batch advances in lockstep
        pos = jnp.full((B,), prompt_len + i, jnp.int32)
        logits, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(tok)
    gen_toks = np.stack([np.asarray(t) for t in outs], axis=1)
    print("prompts:", np.asarray(prompts)[:, :8], "...")
    print("generated:", gen_toks)
    print(f"served batch={B} with TP={dcfg.tp_size}, int8 KV cache, "
          f"{gen} greedy steps")


if __name__ == "__main__":
    main()
